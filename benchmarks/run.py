"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the harness contract).

  Fig 4a -> bench_latency      Fig 4b -> bench_breakdown
  Fig 5a -> bench_nearstorage  Fig 5b -> bench_utilization
  (ours)  -> bench_kernels, roofline (from dry-run artifacts),
             bench_pipeline (serial vs pipelined vs fused-pipelined
             near-data executor: window prefetch overlap + the fused
             predicate/compact device pass), bench_cluster (1->8 node
             scatter-gather scaling + result-cache warm/cold),
             bench_prune (zone-map predicate pushdown: pruned vs
             reference on selective / accept-all / undecidable queries),
             bench_scaling (multi-shard)
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_breakdown,
        bench_cluster,
        bench_kernels,
        bench_latency,
        bench_nearstorage,
        bench_pipeline,
        bench_prune,
        bench_scaling,
        bench_utilization,
        roofline,
    )

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for mod, label in [
        (bench_latency, "Fig4a latency"),
        (bench_breakdown, "Fig4b breakdown"),
        (bench_nearstorage, "Fig5a near-storage"),
        (bench_utilization, "Fig5b utilization"),
        (bench_kernels, "kernel micro"),
        (bench_pipeline, "pipelined/fused executor"),
        (bench_cluster, "distributed skim cluster"),
        (bench_prune, "zone-map predicate pushdown"),
        (bench_scaling, "beyond-paper scaling/overlap"),
    ]:
        print(f"# --- {label} ---", file=sys.stderr)
        mod.run()
    print("# --- roofline (from dry-run artifacts) ---", file=sys.stderr)
    roofline.run()
    print(f"# total {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
