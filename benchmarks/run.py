"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the harness contract).

  Fig 4a -> bench_latency      Fig 4b -> bench_breakdown
  Fig 5a -> bench_nearstorage  Fig 5b -> bench_utilization
  (ours)  -> bench_kernels,
             bench_pipeline (serial vs pipelined vs fused-pipelined
             near-data executor: window prefetch overlap + the fused
             predicate/compact device pass), bench_cluster (1->8 node
             scatter-gather scaling + result-cache warm/cold),
             bench_prune (zone-map predicate pushdown: pruned vs
             reference on selective / accept-all / undecidable queries),
             bench_expr (derived-expression tier: Z-window skim, fused
             vs staged and pruned vs reference),
             bench_cascade (cascaded phase-1 execution vs the
             fused+pruned preload path),
             bench_device (device-resident batched cascade: one
             dispatch per window-batch, on-device basket decode,
             survivor masks resident between stages),
             bench_service (async job service: time-to-first-partial
             vs blocking, admission pricing, queue throughput),
             bench_obs (trace/metrics layer: no-op tracer overhead
             bound + deterministic Chrome-trace export of a traced
             service drain),
             bench_faults (fault-tolerance costs: hedged straggler
             makespan, corrupt-basket retry path, checksum overhead
             vs the 2% budget),
             bench_scaling (multi-shard)

Module selection (CI and the 2-core dev host pay for one figure, not the
suite)::

    python benchmarks/run.py --only prune,expr          # just these two
    python benchmarks/run.py --skip kernels             # all but these
    python benchmarks/run.py --only expr --smoke        # shrunken store

``--json [PATH]`` additionally writes every emitted row — modeled times
and bytes moved — to a machine-readable ``BENCH_<pr>.json`` (default
name), the perf-trajectory artifact CI uploads per PR.  After writing,
every realized ``*/wall`` row is compared against the latest committed
``BENCH_<n>.json`` baseline; a >20% regression prints a loud warning
(warning, not failure: realized walls on shared CI cores are noisy —
the deterministic byte/identity contracts live in the benches).
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import re
import sys
import time

# Benchmarks measure the execution path, never the test-time verifier:
# REPRO_VERIFY is forced off here so an ambient setting (e.g. a shell
# that just ran the test suite) cannot skew the modeled-vs-wall rows.
os.environ["REPRO_VERIFY"] = "0"

# The PR this tree's benchmark artifact belongs to (BENCH_<pr>.json).
# The ``PR_NUMBER`` env var overrides the in-tree value; an *empty*
# override fails loudly in main() instead of silently skipping the
# artifact (the PR-9 trajectory gap: no BENCH_9.json was ever emitted).
PR_NUMBER: str | int | None = os.environ.get("PR_NUMBER", 10)


def resolve_pr_number() -> int:
    """The artifact's PR number, or a loud SystemExit when unset."""
    raw = PR_NUMBER
    if raw is None or str(raw).strip() == "":
        raise SystemExit(
            "PR_NUMBER is unset: benchmarks/run.py cannot name its "
            "BENCH_<pr>.json artifact.  Set the PR_NUMBER env var (CI) or "
            "the in-tree default in benchmarks/run.py."
        )
    try:
        return int(str(raw).strip())
    except ValueError:
        raise SystemExit(f"PR_NUMBER={raw!r} is not an integer")


def _modules() -> list[tuple[str, str, str]]:
    """(short name, module attr, figure label) in run order."""
    return [
        ("latency", "bench_latency", "Fig4a latency"),
        ("breakdown", "bench_breakdown", "Fig4b breakdown"),
        ("nearstorage", "bench_nearstorage", "Fig5a near-storage"),
        ("utilization", "bench_utilization", "Fig5b utilization"),
        ("kernels", "bench_kernels", "kernel micro"),
        ("pipeline", "bench_pipeline", "pipelined/fused executor"),
        ("cluster", "bench_cluster", "distributed skim cluster"),
        ("prune", "bench_prune", "zone-map predicate pushdown"),
        ("expr", "bench_expr", "derived-expression tier"),
        ("cascade", "bench_cascade", "cascaded phase-1 execution"),
        ("device", "bench_device", "device-resident batched cascade"),
        ("service", "bench_service", "async skim job service"),
        ("obs", "bench_obs", "trace/metrics layer"),
        ("faults", "bench_faults", "fault tolerance: hedging + checksums"),
        ("scaling", "bench_scaling", "beyond-paper scaling/overlap"),
    ]


def _parse_names(raw: str | None, known: list[str]) -> set[str]:
    if not raw:
        return set()
    names = {n.strip() for n in raw.split(",") if n.strip()}
    unknown = names - set(known)
    if unknown:
        raise SystemExit(
            f"unknown benchmark(s): {sorted(unknown)}; known: {known}"
        )
    return names


#: regression threshold for realized ``*/wall`` rows vs the committed
#: baseline artifact (warn-only: shared-core walls are noisy)
WALL_REGRESSION = 0.20


def _wall_rows(doc: dict) -> dict[str, float]:
    """``name -> value`` for every realized ``*/wall`` row in a BENCH doc."""
    rows: dict[str, float] = {}
    for mod in doc.get("benchmarks", {}).values():
        for row in mod.get("rows", ()):
            name = row.get("name", "")
            if name.endswith("/wall"):
                rows[name] = float(row["value"])
    return rows


def _latest_baseline(pr: int) -> tuple[str, dict] | None:
    """The committed ``BENCH_<n>.json`` with the highest ``n`` below the
    current PR (trace exports and the artifact being written excluded)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    best: tuple[int, str] | None = None
    for fname in os.listdir(root):
        m = re.fullmatch(r"BENCH_(\d+)\.json", fname)
        if not m or int(m.group(1)) >= pr:
            continue
        if best is None or int(m.group(1)) > best[0]:
            best = (int(m.group(1)), fname)
    if best is None:
        return None
    with open(os.path.join(root, best[1])) as fh:
        return best[1], json.load(fh)


def compare_walls(doc: dict, pr: int) -> list[str]:
    """Warn-lines for realized ``*/wall`` rows that regressed >20% vs the
    latest committed baseline artifact (empty list = clean)."""
    base = _latest_baseline(pr)
    if base is None:
        return []
    base_name, base_doc = base
    if bool(base_doc.get("smoke")) != bool(doc.get("smoke")):
        return []  # smoke and full walls are not comparable
    baseline = _wall_rows(base_doc)
    warnings: list[str] = []
    for name, value in sorted(_wall_rows(doc).items()):
        ref = baseline.get(name)
        if ref is None or ref <= 0:
            continue
        if value > ref * (1.0 + WALL_REGRESSION):
            warnings.append(
                f"# WARN wall regression: {name} {value:.1f}us vs "
                f"{ref:.1f}us in {base_name} (+{(value / ref - 1) * 100:.0f}%,"
                f" threshold +{WALL_REGRESSION * 100:.0f}%)"
            )
    return warnings


def main(argv: list[str] | None = None) -> None:
    known = [name for name, _, _ in _modules()]
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", help=f"comma-separated subset of {known}")
    ap.add_argument("--skip", help="comma-separated modules to leave out")
    ap.add_argument(
        "--smoke", action="store_true",
        help="pass smoke mode (shrunken store) to modules that support it",
    )
    ap.add_argument(
        "--json", nargs="?", const="", default=None, metavar="PATH",
        help="write the emitted rows as machine-readable JSON "
        "(default path: BENCH_<pr>.json from PR_NUMBER)",
    )
    args = ap.parse_args(argv)
    if args.json is not None and not args.json:
        args.json = f"BENCH_{resolve_pr_number()}.json"
    only = _parse_names(args.only, known)
    skip = _parse_names(args.skip, known)
    if only & skip:
        raise SystemExit(f"--only and --skip overlap: {sorted(only & skip)}")

    import benchmarks
    from benchmarks import common

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    per_module: dict[str, dict] = {}
    for name, attr, label in _modules():
        if (only and name not in only) or name in skip:
            continue
        __import__(f"benchmarks.{attr}")
        mod = getattr(benchmarks, attr)
        print(f"# --- {label} ---", file=sys.stderr)
        kwargs = (
            {"smoke": True}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters
            else {}
        )
        row0 = len(common.BENCH_ROWS)
        t_mod = time.perf_counter()
        mod.run(**kwargs)
        per_module[name] = {
            "label": label,
            "wall_s": time.perf_counter() - t_mod,
            "rows": common.BENCH_ROWS[row0:],
        }
    total_s = time.perf_counter() - t0
    print(f"# total {total_s:.1f}s", file=sys.stderr)

    if args.json:
        pr = resolve_pr_number()
        doc = {
            "pr": pr,
            "smoke": bool(args.smoke),
            "total_wall_s": total_s,
            "benchmarks": per_module,
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
        for line in compare_walls(doc, pr):
            print(line, file=sys.stderr)


if __name__ == "__main__":
    main()
