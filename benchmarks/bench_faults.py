"""Fault-tolerance costs: hedged stragglers + checksum overhead.

Two claims from DESIGN.md §14, benchmarked:

  * **Hedging cuts the modeled straggler makespan.**  A 4-node
    replicated cluster with one injected straggler runs twice — without
    hedging (makespan = the straggler) and with a quantile hedge
    (makespan = hedge delay + the replica).  Both results are
    bit-identical; the hedged makespan must be strictly smaller.
  * **Integrity verification costs <=2% of a skim.**  Every basket
    fetch recomputes a CRC-32 against the encode-time digest
    (``EventStore.verify``).  A full near-data skim with verification
    on vs off bounds the end-to-end overhead; CRC-32 on the compressed
    blob is cheap next to decode + kernels + output encode.

Reported rows: unhedged vs hedged modeled makespan (and the win
ledger), retry-path modeled cost for a corrupt basket, and the measured
verify overhead percentage.

``--smoke`` shrinks the store for CI.
"""

from __future__ import annotations

import time

from benchmarks import common
from benchmarks.common import QUERY, csv_row
from repro.cluster import HedgePolicy, build_cluster
from repro.core.engine import LOCAL_DISK

N_NODES = 4
STRAGGLE_S = 30.0
VERIFY_REPEATS = 5
#: the DESIGN.md §14 budget: integrity verification <= 2% of decode
VERIFY_BUDGET = 0.02


def _straggler_cluster(store, hedge=None):
    coord = build_cluster(
        store, N_NODES, replication=True, near_input_link=LOCAL_DISK,
        hedge=hedge,
    )
    coord.nodes[1].inject_fault("straggle", delay_s=STRAGGLE_S)
    return coord


def _skim_sweep(store) -> float:
    """Seconds for one full near-data skim (min-of-N).

    The decode cache is disabled for the measurement — a cache hit
    skips the decode but not the fetch-time digest check, which would
    inflate the apparent verify share far past what any cold read pays.
    """
    from repro.core.engine import run_skim

    saved = store.decode_cache_baskets
    store.decode_cache_baskets = 0
    store._decode_cache.clear()
    try:
        best = float("inf")
        for _ in range(VERIFY_REPEATS):
            t0 = time.perf_counter()
            run_skim(store, QUERY, mode="near_data")
            best = min(best, time.perf_counter() - t0)
        return best
    finally:
        store.decode_cache_baskets = saved


def run(smoke: bool = False) -> dict:
    if smoke:
        common.N_EVENTS = min(common.N_EVENTS, 20_000)
    store = common.get_store("bitpack")

    # -- hedged straggler makespan -------------------------------------
    clean = build_cluster(
        store, N_NODES, replication=False, near_input_link=LOCAL_DISK
    ).run(QUERY)
    base = max(r.modeled_s for r in clean.responses)

    unhedged = _straggler_cluster(store).run(QUERY)
    hedge = HedgePolicy(delay_s=base * 1.5)
    hedged_res = _straggler_cluster(store, hedge=hedge).run(QUERY)

    assert unhedged.n_passed == hedged_res.n_passed == clean.n_passed
    assert hedged_res.extras["hedges_won"] >= 1
    assert hedged_res.modeled_total_s < unhedged.modeled_total_s, (
        "hedging must cut the modeled straggler makespan"
    )
    speedup = unhedged.modeled_total_s / hedged_res.modeled_total_s
    csv_row(
        "faults/straggler/unhedged", unhedged.modeled_total_s * 1e6,
        f"one {STRAGGLE_S:.0f}s modeled straggler dominates",
    )
    csv_row(
        "faults/straggler/hedged", hedged_res.modeled_total_s * 1e6,
        f"hedge delay + replica; {speedup:.1f}x faster, "
        f"won={hedged_res.extras['hedges_won']}",
    )

    # -- corrupt-basket retry path -------------------------------------
    coord = build_cluster(
        store, N_NODES, replication=True, near_input_link=LOCAL_DISK,
        prune=False,
    )
    coord.nodes[1].inject_fault("corrupt")
    res = coord.run(QUERY)
    assert res.n_passed == clean.n_passed
    assert res.extras["corrupt_baskets"] == 1
    csv_row(
        "faults/corrupt/retried", res.modeled_total_s * 1e6,
        f"replica re-fetch, backoff={res.extras['retry_backoff_s']:.3f}s",
    )

    # -- checksum overhead ---------------------------------------------
    store.verify = True
    with_verify = _skim_sweep(store)
    store.verify = False
    without = _skim_sweep(store)
    store.verify = True
    overhead = with_verify / without - 1.0
    csv_row(
        "faults/verify/overhead_pct", overhead * 100.0,
        f"CRC-32 per fetch vs unchecked skim (budget "
        f"{VERIFY_BUDGET * 100:.0f}%)",
    )
    assert overhead <= VERIFY_BUDGET, (
        f"integrity verification overhead {overhead * 100:.2f}% exceeds "
        f"the {VERIFY_BUDGET * 100:.0f}% budget"
    )

    return {
        "unhedged_s": unhedged.modeled_total_s,
        "hedged_s": hedged_res.modeled_total_s,
        "hedge_speedup": speedup,
        "verify_overhead": overhead,
    }


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke=True)
