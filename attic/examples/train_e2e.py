"""End-to-end training driver: a ~100M-param model on the skim-fed
pipeline, with checkpoints, crash recovery, and deterministic resume.

Defaults are sized for this CPU container (a scaled-down model, a few
hundred steps); pass --model-dim/--layers/--steps to scale up on a real
fleet.  The full production path (dry-run of the 16x16 / 2x16x16 meshes)
lives in repro.launch.dryrun.

Run: PYTHONPATH=src python examples/train_e2e.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import SkimTokenPipeline
from repro.data.synth import make_nanoaod_like
from repro.models.model import init_params
from repro.train import checkpoint as ckpt
from repro.train.fault import resume
from repro.train.loop import TrainConfig, train_loop
from repro.train.optim import AdamWConfig

QUERY = {
    "branches": ["Electron_*", "Muon_*", "Jet_*", "MET_*", "HLT_*"],
    "selection": {
        "preselection": [{"branch": "nElectron", "op": ">=", "value": 1}],
        "object": [
            {
                "collection": "Electron",
                "cuts": [
                    {"var": "pt", "op": ">", "value": 20.0},
                    {"var": "eta", "op": "abs<", "value": 2.4},
                ],
                "min_count": 1,
            }
        ],
        "event": [{"type": "cut", "branch": "MET_pt", "op": ">", "value": 15.0}],
    },
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--model-dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config("gemma3-1b", smoke=True).with_(
        name="e2e",
        n_layers=args.layers,
        d_model=args.model_dim,
        n_heads=max(args.model_dim // 64, 2),
        n_kv_heads=1,
        head_dim=64,
        d_ff=args.model_dim * 4,
        vocab=args.vocab,
        window=128,
        mixer_pattern=("attn_local", "attn_local", "attn"),
        loss_chunk=128,
    )

    store = make_nanoaod_like(60_000, n_hlt=32, n_filler=16, seed=7)
    pipe = SkimTokenPipeline(
        store, QUERY, cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    print(
        f"[e2e] skim front-end kept {pipe.stats.events_kept}/"
        f"{pipe.stats.events_seen} events"
    )

    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[e2e] model '{cfg.name}': {n/1e6:.1f}M params")

    params, start = resume(params, args.ckpt_dir)
    if start:
        print(f"[e2e] resuming from step {start}")

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        optim=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        log_every=10,
        ckpt_every=50,
        ckpt_dir=args.ckpt_dir,
    )
    t0 = time.perf_counter()
    params, _, hist = train_loop(
        cfg,
        params,
        lambda s: {k: jnp.asarray(v) for k, v in pipe.batch(s).items()},
        tcfg,
        n_steps=args.steps,
        start_step=start,
        save_fn=lambda p, o, s: ckpt.save({"params": p}, s, args.ckpt_dir),
    )
    dt = time.perf_counter() - t0
    tok = (args.steps - start) * args.batch * args.seq
    print(
        f"[e2e] {tok/dt:.0f} tok/s; loss {hist[0]['loss']:.3f} -> "
        f"{hist[-1]['loss']:.3f}"
    )


if __name__ == "__main__":
    main()
