"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:

    compute    = FLOPs_per_device / 197 TFLOP/s (bf16)
    memory     = bytes_per_device / 819 GB/s  (HBM)
    collective = collective_bytes_per_device / 50 GB/s (ICI per link)

FLOPs/bytes come from the trip-count-corrected probe totals (the raw
``cost_analysis`` of a scanned program counts loop bodies once — see
launch/dryrun.py).  MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill),
2*N*B (decode) with N = active params for MoE.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}


def model_flops(arch: str, shape_name: str, n_devices: int) -> float:
    """Useful-work FLOPs per device."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    counts = cfg.param_counts()
    n_active = counts["active"]
    # embeddings do ~2 matmul-equivalents; 6ND already folds this in roughly
    if spec.kind == "train":
        total = 6.0 * n_active * spec.global_batch * spec.seq_len
    elif spec.kind == "prefill":
        total = 2.0 * n_active * spec.global_batch * spec.seq_len
    else:  # decode: one token per sequence
        total = 2.0 * n_active * spec.global_batch
    return total / n_devices


def analyze_record(rec: dict) -> dict:
    corr = rec.get("corrected")
    prod = rec["production"]
    if corr:
        flops = corr["flops"]
        byts = corr["bytes"]
        coll = corr["collective_bytes"]
    else:
        flops = prod["cost"].get("flops", 0.0)
        byts = prod["cost"].get("bytes", 0.0)
        coll = prod["collectives"]["total_bytes"]

    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_x)
    mf = model_flops(rec["arch"], rec["shape"], rec["n_devices"])
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dominant,
        "roofline_fraction": (t_c / bound) if bound else 0.0,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "step_time_lower_bound_s": bound,
    }


def load_all(dryrun_dir: str = "experiments/dryrun", mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        rows.append(analyze_record(rec))
    return rows


def run(dryrun_dir: str = "experiments/dryrun") -> list:
    rows = load_all(dryrun_dir)
    if not rows:
        print("roofline: no dry-run artifacts found (run repro.launch.dryrun)")
        return []
    hdr = (
        f"{'arch':<18} {'shape':<12} {'compute':>10} {'memory':>10} "
        f"{'collect':>10} {'dominant':>10} {'roof%':>6} {'useful%':>8}"
    )
    print(hdr)
    for r in rows:
        print(
            f"{r['arch']:<18} {r['shape']:<12} {r['compute_s']:>10.4f} "
            f"{r['memory_s']:>10.4f} {r['collective_s']:>10.4f} "
            f"{r['dominant']:>10} {100*r['roofline_fraction']:>5.1f} "
            f"{100*min(r['useful_ratio'],9.99):>7.1f}"
        )
    return rows


if __name__ == "__main__":
    run()
