"""Emit EXPERIMENTS.md-ready markdown from the dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.summarize [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import analyze_record

GB = 1e9


def _load(d):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append((os.path.basename(p), json.load(f)))
    return recs


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | strat | compile s | args GB/dev | temp GB/dev | AG | AR | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for name, rec in recs:
        mesh = "x".join(str(v) for v in rec["mesh"].values())
        strat = rec.get("strategy", "tp")
        prod = rec["production"]
        mem = prod["memory"]
        c = prod["collectives"]["counts"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {mesh} | {strat} "
            f"| {prod['compile_s']:.1f} "
            f"| {mem.get('argument_size_in_bytes', 0)/GB:.2f} "
            f"| {mem.get('temp_size_in_bytes', 0)/GB:.2f} "
            f"| {c['all-gather']} | {c['all-reduce']} | {c['reduce-scatter']} "
            f"| {c['all-to-all']} | {c['collective-permute']} |"
        )
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | roofline % | useful % | step bound s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for name, rec in recs:
        if rec.get("strategy", "tp") != "tp" or "corrected" not in rec:
            continue
        if not name.endswith("__single.json"):
            continue
        r = analyze_record(rec)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} "
            f"| {100*r['roofline_fraction']:.1f} | {100*min(r['useful_ratio'], 9.99):.1f} "
            f"| {r['step_time_lower_bound_s']:.3f} |"
        )
    return "\n".join(lines)


def strategy_table(recs) -> str:
    by_key = {}
    for name, rec in recs:
        if "corrected" not in rec:
            continue
        key = (rec["arch"], rec["shape"])
        by_key.setdefault(key, {})[rec.get("strategy", "tp")] = rec
    lines = [
        "| arch | shape | strategy | compute s | memory s | collective s | roofline % |",
        "|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), strats in sorted(by_key.items()):
        if len(strats) < 2:
            continue
        for strat, rec in sorted(strats.items()):
            r = analyze_record(rec)
            lines.append(
                f"| {arch} | {shape} | {strat} | {r['compute_s']:.4f} "
                f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
                f"| {100*r['roofline_fraction']:.1f} |"
            )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "strategy"])
    args = ap.parse_args()
    recs = _load(args.dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run cells\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod baseline)\n")
        print(roofline_table(recs))
        print()
    if args.section in ("all", "strategy"):
        print("### Strategy comparison (hillclimbed pairs)\n")
        print(strategy_table(recs))


if __name__ == "__main__":
    main()
