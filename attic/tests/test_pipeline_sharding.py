"""Input-pipeline determinism + sharding-rule validity for every arch."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.data.pipeline import SkimTokenPipeline, TokenPipeline
from repro.data.synth import make_nanoaod_like
from repro.models.model import init_cache, init_params
from tests.test_query import QUERY


def test_token_pipeline_deterministic():
    p1 = TokenPipeline(1000, 64, 4, seed=11)
    p2 = TokenPipeline(1000, 64, 4, seed=11)
    b1, b2 = p1.batch(42), p2.batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(1)["tokens"], p1.batch(2)["tokens"])


def test_labels_are_shifted_tokens():
    b = TokenPipeline(1000, 64, 4).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_skim_pipeline_end_to_end():
    store = make_nanoaod_like(8000, n_hlt=16, seed=2)
    pipe = SkimTokenPipeline(store, QUERY, vocab=512, seq_len=32, global_batch=4)
    assert 0 < pipe.stats.events_kept < pipe.stats.events_seen
    b = pipe.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 512
    b2 = SkimTokenPipeline(
        store, QUERY, vocab=512, seq_len=32, global_batch=4
    ).batch(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])  # deterministic


# ---------------------------------------------------------------------------
# sharding rules: structural validity for every arch on the production mesh
# (no devices needed — specs are checked against shapes for divisibility)
# ---------------------------------------------------------------------------


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    from repro.distributed.sharding import _param_spec

    cfg = get_config(arch)
    sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    mesh = FakeMesh()

    def walk(tree, path):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
        elif isinstance(tree, (list, tuple)):
            for i, v in enumerate(tree):
                walk(v, path + (str(i),))
        else:
            spec = _param_spec(path, tree, mesh)
            off = 1 if "blocks" in path else 0
            for i, axis in enumerate(spec):
                if axis is None:
                    continue
                axes = axis if isinstance(axis, tuple) else (axis,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert tree.shape[i] % n == 0, (path, tree.shape, spec)

    walk(sds, ())


@pytest.mark.parametrize("arch", ["deepseek_67b", "gemma3_1b", "jamba_1p5_large"])
@pytest.mark.parametrize("shape", ["decode_32k"])
def test_cache_specs_divisible(arch, shape):
    from repro.distributed.sharding import _cache_spec

    cfg = get_config(arch)
    spec_shape = SHAPES[shape]
    sds = jax.eval_shape(
        lambda: init_cache(cfg, spec_shape.global_batch, spec_shape.seq_len)
    )
    mesh = FakeMesh()

    def walk(tree, path):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
        elif isinstance(tree, (list, tuple)):
            for i, v in enumerate(tree):
                walk(v, path + (str(i),))
        else:
            spec = _cache_spec(path, tree, mesh)
            for i, axis in enumerate(spec):
                if axis is None:
                    continue
                axes = axis if isinstance(axis, tuple) else (axis,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert tree.shape[i] % n == 0, (path, tree.shape, spec)

    walk(sds, ())


def test_big_embeddings_are_sharded():
    from repro.distributed.sharding import _param_spec

    cfg = get_config("gemma3_1b")
    sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    spec = _param_spec(("embed",), sds["embed"], FakeMesh())
    assert spec[0] == "model"  # 262k vocab must not replicate
