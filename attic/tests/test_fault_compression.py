import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.compression import (
    dequantize_int8,
    ef_quantize,
    ef_quantize_tree,
    quantize_int8,
    topk_sparsify,
)
from repro.train.fault import FailureInjector, ShardHealth, rebalance


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (64, 64)), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_accumulates():
    """EF: the sum of quantized estimates converges to the true sum."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
    err = jnp.zeros_like(g_true)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        g_hat, err = ef_quantize(g_true, err)
        total = total + g_hat
    np.testing.assert_allclose(
        np.asarray(total) / 50, np.asarray(g_true), atol=0.02
    )


def test_ef_tree_api():
    grads = {"a": jnp.ones((8,)), "b": {"c": jnp.full((4,), -2.0)}}
    g_hat, errs = ef_quantize_tree(grads)
    assert jax.tree.structure(g_hat) == jax.tree.structure(grads)
    for g, gh in zip(jax.tree.leaves(grads), jax.tree.leaves(g_hat)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(gh), atol=0.05)


@given(st.integers(0, 10_000), st.floats(0.01, 0.5))
@settings(max_examples=20, deadline=None)
def test_topk_property(seed, frac):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(40, 25)), jnp.float32)
    s = np.asarray(topk_sparsify(g, frac))
    nnz = (s != 0).sum()
    assert nnz <= int(g.size * frac) + 25  # ties may keep a few extra
    # kept entries are the largest-magnitude ones
    if nnz:
        assert np.abs(s[s != 0]).min() >= np.abs(np.asarray(g)[s == 0]).max() - 1e-6


def test_shard_health_straggler_detection():
    h = ShardHealth(8)
    for _ in range(10):
        for s in range(8):
            h.observe(s, 5.0 if s == 3 else 1.0)
    assert h.is_straggler(3)
    assert not h.is_straggler(0)


def test_rebalance_steals_from_straggler():
    h = ShardHealth(4)
    for _ in range(10):
        for s in range(4):
            h.observe(s, 10.0 if s == 0 else 1.0)
    assignments = {0: list(range(8)), 1: [], 2: [], 3: []}
    out = rebalance(assignments, h)
    assert len(out[0]) == 4  # half stolen
    assert sum(len(v) for v in out.values()) == 8  # nothing lost
    assert all(len(out[s]) > 0 for s in (1, 2, 3))


def test_failure_injector_fires_once():
    inj = FailureInjector([3])
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second pass: already fired


def test_compressed_psum_single_device():
    """On a 1-device mesh the compressed reduce must be ~identity."""
    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("pod",))
    fn = compressed_psum(mesh, "pod")
    x = jnp.asarray(np.random.default_rng(0).normal(0, 2, (32, 32)), jnp.float32)
    with mesh:
        y = fn(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.05)
