"""End-to-end behaviour tests: the paper's workflow plus skim -> train."""

import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core import SkimEngine, WAN_1G, run_skim
from repro.data.pipeline import SkimTokenPipeline
from repro.data.synth import make_nanoaod_like
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine
from repro.train.fault import FailureInjector, resume
from repro.train.loop import TrainConfig, make_train_step, train_loop
from repro.train.optim import AdamWConfig
from repro.train import checkpoint as ckpt
from tests.test_query import QUERY


def test_paper_workflow_json_roundtrip(tmp_path):
    """Fig. 3: JSON query in -> reduced ROOT-like file out."""
    store = make_nanoaod_like(10_000, n_hlt=16, n_filler=4)
    qjson = json.dumps(QUERY)  # queries arrive as JSON text (HTTP POST body)
    res = run_skim(store, qjson, mode="near_data")
    out_path = str(tmp_path / "skimmed.skim")
    res.output.save(out_path)
    from repro.data.store import EventStore

    reloaded = EventStore.load(out_path)
    assert reloaded.n_events == res.n_passed
    # output is orders of magnitude smaller — the paper's data-reduction claim
    assert reloaded.compressed_bytes() < 0.1 * store.compressed_bytes()


def test_speedup_structure_matches_paper():
    """Qualitative Fig. 4: near_data >> client_opt > client_plain at 1 Gb/s."""
    store = make_nanoaod_like(30_000, n_hlt=32, n_filler=30, basket_events=4096)
    eng = SkimEngine(store, input_link=WAN_1G)
    t = {m: eng.run(QUERY, m).breakdown.total() for m in
         ("client_plain", "client_opt", "near_data")}
    assert t["near_data"] < t["client_opt"] < t["client_plain"]
    assert t["client_plain"] / t["near_data"] > 4  # 44.3x at paper scale


def test_skim_to_train_end_to_end():
    """Train a model on skimmed physics tokens; loss must fall."""
    cfg = get_config("gemma3_1b", smoke=True)
    store = make_nanoaod_like(8000, n_hlt=8, seed=1)
    pipe = SkimTokenPipeline(store, QUERY, cfg.vocab, seq_len=32, global_batch=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(optim=AdamWConfig(lr=5e-3, warmup_steps=0))
    import jax.numpy as jnp

    def data_iter(step):
        b = pipe.batch(step % 2)  # tiny corpus: revisit batches
        return {k: jnp.asarray(v) for k, v in b.items()}

    params, _, hist = train_loop(
        cfg, params, data_iter, tcfg, n_steps=8, log_fn=lambda s: None
    )
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_crash_restart_is_deterministic(tmp_path):
    """Kill at step 5, resume from checkpoint, final params must match an
    uninterrupted run exactly (bitwise)."""
    import jax.numpy as jnp

    cfg = get_config("granite_20b", smoke=True)
    d = str(tmp_path)
    tcfg = TrainConfig(optim=AdamWConfig(lr=1e-3, warmup_steps=0))

    from repro.data.pipeline import TokenPipeline
    from repro.train.optim import adamw_init

    pipe = TokenPipeline(cfg.vocab, 32, 4, seed=9)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    def run(n_steps, params, opt, start=0, save_every=None, injector=None):
        for s in range(start, n_steps):
            if injector:
                injector.maybe_fail(s)
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            params, opt, _ = step_fn(params, opt, batch, jnp.int32(s))
            if save_every and (s + 1) % save_every == 0:
                ckpt.save({"params": params, "opt": opt}, s, d)
        return params, opt

    params0 = init_params(cfg, jax.random.PRNGKey(1))
    opt0 = adamw_init(params0)

    # uninterrupted reference
    ref_params, _ = run(8, params0, opt0)

    # crashy run: checkpoint every 2 steps, die at step 5, resume
    inj = FailureInjector([5])
    try:
        run(8, params0, opt0, save_every=2, injector=inj)
        raise AssertionError("injector did not fire")
    except RuntimeError:
        pass
    tree, start = resume({"params": params0, "opt": opt0}, d)
    out_params, _ = run(8, tree["params"], tree["opt"], start=start)

    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(out_params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_serve_engine_batched_requests():
    cfg = get_config("gemma3_1b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=2, s_max=32)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                max_new=5)
        for i in range(4)
    ]
    done = eng.run(reqs)
    assert len(done) == 4
    assert all(len(r.out) == 5 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)
