"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, output shapes + no NaNs; decode/forward
consistency for the serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells, get_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)
from repro.models.model import generate, logits_fn, prefill

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _inputs(cfg):
    if cfg.frontend_dim:
        tokens = jax.random.normal(KEY, (B, S, cfg.frontend_dim), jnp.float32)
    else:
        tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0, cfg.vocab)
    return tokens, labels


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    tokens, labels = _inputs(cfg)
    h, aux = jax.jit(forward, static_argnames="cfg")(params, cfg, tokens)
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))
    loss = jax.jit(loss_fn, static_argnames="cfg")(params, cfg, tokens, labels)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 2.5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    tokens, labels = _inputs(cfg)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda q: loss_fn(q, cfg, tokens, labels))(p)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw.astype(w.dtype), p, g)
        return p, l

    losses = []
    for _ in range(5):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses  # memorizes one batch


@pytest.mark.parametrize(
    "arch",
    ["gemma3_1b", "jamba_1p5_large", "xlstm_1p3b", "deepseek_v2_236b", "granite_20b"],
)
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the parallel forward logits."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 12), 0, cfg.vocab)

    full = logits_fn(params, cfg, tokens, last_only=False)  # (1, S, V)

    cache = init_cache(cfg, 1, 16)
    got = []
    for t in range(tokens.shape[1]):
        logits, cache = decode_step(
            params, cfg, cache, tokens[:, t : t + 1], jnp.array([t], jnp.int32)
        )
        got.append(np.asarray(logits[0, 0]))
    got = np.stack(got)
    want = np.asarray(full[0])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_prefill_matches_decode_path():
    cfg = get_config("gemma3_1b", smoke=True)
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    logits, cache = prefill(params, cfg, tokens, s_max=16)
    # continue one step; must equal forward over 9 tokens
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    step_logits, _ = decode_step(
        params, cfg, cache, nxt, jnp.array([8], jnp.int32)
    )
    full = logits_fn(params, cfg, jnp.concatenate([tokens, nxt], 1), last_only=True)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, 0]), rtol=2e-3, atol=2e-3
    )


def test_generate_runs():
    cfg = get_config("granite_20b", smoke=True)
    params = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 6), 0, cfg.vocab)
    out = generate(params, cfg, prompt, 5)
    assert out.shape == (2, 5)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < cfg.vocab))


def test_local_attention_window_respected():
    """With a sliding window, distant tokens must not influence logits."""
    cfg = get_config("gemma3_1b", smoke=True).with_(
        mixer_pattern=("attn_local",), window=4, n_layers=2
    )
    params = init_params(cfg, KEY)
    t1 = jax.random.randint(KEY, (1, 16), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab)  # mutate far-away token
    l1 = logits_fn(params, cfg, t1, last_only=True)
    l2 = logits_fn(params, cfg, t2, last_only=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


def test_encoder_is_bidirectional():
    cfg = get_config("hubert_xlarge", smoke=True)
    params = init_params(cfg, KEY)
    x = jax.random.normal(KEY, (1, 10, cfg.frontend_dim), jnp.float32)
    x2 = x.at[:, -1].set(0.0)  # change the LAST frame
    h1, _ = forward(params, cfg, x)
    h2, _ = forward(params, cfg, x2)
    # ...must affect the FIRST position (no causal mask)
    assert not np.allclose(np.asarray(h1[:, 0]), np.asarray(h2[:, 0]))


def test_param_counts_match_analytic():
    """Analytic 6ND param model stays within 25% of actual init counts."""
    for arch in ["gemma3_1b", "granite_20b", "qwen2_moe_a2p7b"]:
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_counts()["total"]
        assert abs(actual - analytic) / actual < 0.25, (arch, actual, analytic)


def test_full_config_param_counts():
    """Full (published) configs hit the advertised parameter classes."""
    expect = {
        "xlstm_1p3b": (1.0e9, 2.1e9),
        "deepseek_67b": (55e9, 75e9),
        "starcoder2_7b": (6e9, 9e9),
        "granite_20b": (15e9, 25e9),
        "gemma3_1b": (0.8e9, 1.6e9),
        "deepseek_v2_236b": (190e9, 280e9),
        "chameleon_34b": (28e9, 40e9),
        "qwen2_moe_a2p7b": (10e9, 20e9),
        "jamba_1p5_large": (300e9, 480e9),
        "hubert_xlarge": (0.7e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: init_params(c, KEY))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
        assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]")


def test_cells_applicability():
    assert "long_500k" not in cells("deepseek-67b")
    assert "long_500k" in cells("xlstm-1.3b")
    assert "decode_32k" not in cells("hubert-xlarge")
    assert len([c for a in ARCHS for c in cells(a)]) >= 30
