import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.train import checkpoint as ckpt
from repro.train.fault import resume

CFG = get_config("granite_20b", smoke=True)
KEY = jax.random.PRNGKey(3)


def test_save_restore_roundtrip(tmp_path):
    params = init_params(CFG, KEY)
    d = str(tmp_path)
    ckpt.save({"params": params}, 7, d)
    tree, meta = ckpt.restore({"params": params}, 7, d)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_multi_shard_roundtrip(tmp_path):
    params = init_params(CFG, KEY)
    d = str(tmp_path)
    ckpt.save({"params": params}, 1, d, shards=4)
    tree, _ = ckpt.restore({"params": params}, 1, d)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_and_resume(tmp_path):
    d = str(tmp_path)
    assert ckpt.latest_step(d) is None
    tree = {"x": jnp.arange(4.0)}
    ckpt.save(tree, 3, d)
    ckpt.save(tree, 9, d)
    assert ckpt.latest_step(d) == 9
    _, step = resume(tree, d)
    assert step == 10  # resumes AFTER the checkpointed step


def test_incomplete_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    ckpt.save({"x": jnp.zeros(2)}, 5, d)
    # simulate a crash mid-write: directory without meta.json
    os.makedirs(os.path.join(d, "step_00000009"))
    assert ckpt.latest_step(d) == 5


def test_atomic_overwrite(tmp_path):
    d = str(tmp_path)
    ckpt.save({"x": jnp.zeros(2)}, 5, d)
    ckpt.save({"x": jnp.ones(2)}, 5, d)  # same step again
    tree, _ = ckpt.restore({"x": jnp.zeros(2)}, 5, d)
    np.testing.assert_array_equal(np.asarray(tree["x"]), [1.0, 1.0])


RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt

d = sys.argv[1]
tree = {"w": jnp.arange(64.0).reshape(8, 8)}

# save from a 4x2 mesh
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
sh1 = NamedSharding(mesh1, P("data", "model"))
tree1 = {"w": jax.device_put(tree["w"], sh1)}
ckpt.save(tree1, 0, d)

# restore onto a DIFFERENT 2x4 mesh (elastic re-mesh)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
tree2, _ = ckpt.restore(tree, 0, d, shardings=sh2)
assert tree2["w"].sharding.is_equivalent_to(sh2["w"], 2)
np.testing.assert_array_equal(np.asarray(tree2["w"]), np.asarray(tree["w"]))
print("RESHARD_OK")
"""


def test_reshard_on_load_elastic(tmp_path):
    """Save on a 4x2 mesh, restore onto 2x4 — the elastic-scaling path."""
    env = dict(os.environ)
    # force the CPU platform: images bundling libtpu make an unset
    # JAX_PLATFORMS probe for TPUs for minutes before falling back,
    # blowing the subprocess timeout (host-device forcing needs cpu anyway)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", RESHARD_SCRIPT, str(tmp_path)],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        timeout=300,
    )
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
