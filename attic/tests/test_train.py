import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.train.loop import TrainConfig, make_train_step, train_loop
from repro.train.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr

CFG = get_config("gemma3_1b", smoke=True)
KEY = jax.random.PRNGKey(0)


def _batch(b=4, s=32, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (b, s + 1), 0, CFG.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_train_step_decreases_loss_over_steps():
    params = init_params(CFG, KEY)
    opt = adamw_init(params)
    tcfg = TrainConfig(optim=AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100))
    step_fn = jax.jit(make_train_step(CFG, tcfg))
    batch = _batch()
    losses = []
    for i in range(8):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_accumulation_equivalence():
    """microbatches=4 must produce (numerically close) identical updates."""
    params = init_params(CFG, KEY)
    batch = _batch(b=8)
    outs = {}
    for m in (1, 4):
        opt = adamw_init(params)
        tcfg = TrainConfig(
            microbatches=m, optim=AdamWConfig(lr=1e-3, warmup_steps=0)
        )
        step_fn = jax.jit(make_train_step(CFG, tcfg))
        p2, _, metrics = step_fn(params, opt, batch, jnp.int32(0))
        outs[m] = (p2, float(metrics["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-3
    flat1 = jax.tree.leaves(outs[1][0])
    flat4 = jax.tree.leaves(outs[4][0])
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-4
        )


def test_adamw_matches_reference():
    """Single-tensor AdamW against a straightforward numpy reference."""
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.array([[1.0, -2.0]], jnp.float32)}
    g = {"w": jnp.array([[0.5, 0.5]], jnp.float32)}
    opt = adamw_init(p)
    p2, opt2, _ = adamw_update(cfg, g, opt, p, jnp.int32(0))
    # bias-corrected first step of Adam: update = lr * g/|g| elementwise
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.05 * 0.25 / (1 - 0.95)
    want = np.array([[1.0, -2.0]]) - 0.1 * (m / (np.sqrt(v) + 1e-8))
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=0.001)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    opt = adamw_init(p)
    _, _, metrics = adamw_update(cfg, g, opt, p, jnp.int32(0))
    assert float(metrics["grad_norm"]) == 200.0  # reported pre-clip


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.int32(0))) == 0.0
    assert abs(float(cosine_lr(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(cosine_lr(cfg, jnp.int32(100))) - 0.1) < 1e-3
    mid = float(cosine_lr(cfg, jnp.int32(55)))
    assert 0.1 < mid < 1.0


def test_train_loop_runs_and_logs():
    params = init_params(CFG, KEY)
    tcfg = TrainConfig(optim=AdamWConfig(lr=1e-3), log_every=2)
    logs = []
    params, _, hist = train_loop(
        CFG, params, lambda s: _batch(seed=s), tcfg, n_steps=5,
        log_fn=lambda s: logs.append(s),
    )
    assert len(hist) >= 2 and logs
