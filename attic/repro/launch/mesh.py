"""Production mesh definitions.

A function (never a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to get 512 placeholder devices; smoke tests and benches see the
real single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
