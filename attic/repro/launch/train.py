"""End-to-end training driver.

Example (CPU, smoke scale):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --steps 50 --batch 8 --seq 128

On a real fleet the same driver runs with ``--mesh single|multi`` and the
full config; the data pipeline is the near-data skim front-end when
``--skim-query`` is given, else the deterministic synthetic token stream.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import SkimTokenPipeline, TokenPipeline
from repro.data.synth import make_nanoaod_like
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import init_params
from repro.train import checkpoint as ckpt
from repro.train.fault import resume
from repro.train.loop import TrainConfig, train_loop
from repro.train.optim import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--skim-query", default="", help="JSON query file for the skim pipeline")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.2f}M params, mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    if args.skim_query:
        with open(args.skim_query) as f:
            q = json.load(f)
        store = make_nanoaod_like(50_000, n_hlt=32, seed=args.seed)
        pipe = SkimTokenPipeline(
            store, q, cfg.vocab, args.seq, args.batch, seed=args.seed
        )
        print(
            f"[train] skim pipeline: kept {pipe.stats.events_kept}/"
            f"{pipe.stats.events_seen} events "
            f"({pipe.stats.bytes_scanned/1e6:.1f} MB scanned)"
        )
    else:
        pipe = TokenPipeline(cfg.vocab, args.seq, args.batch, seed=args.seed)

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        optim=AdamWConfig(lr=args.lr, total_steps=args.steps),
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir or "/tmp/repro_ckpt",
    )

    start = 0
    if args.ckpt_dir:
        params, start = resume(params, args.ckpt_dir)
        if start:
            print(f"[train] resumed from step {start}")

    save_fn = None
    if args.ckpt_dir:
        save_fn = lambda p, o, s: ckpt.save(
            {"params": p, "opt": o}, s, args.ckpt_dir
        )

    def data_iter(step):
        b = pipe.batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    params, _, history = train_loop(
        cfg, params, data_iter, tcfg, args.steps, start_step=start,
        mesh=mesh, save_fn=save_fn,
    )
    print(f"[train] done; final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
