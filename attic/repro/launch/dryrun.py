import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Debug override (small fleets compile faster while iterating); production
# dry-runs use the 512 default above.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production mesh; record memory/cost/collective analysis.

Two artifacts per cell:

  * **production compile** — the deployment config (scan-over-layers,
    remat, chunked loss/attention/MoE).  Its success proves the sharding
    is coherent; its ``memory_analysis`` is the fits-in-HBM evidence.
  * **cost probes** — XLA's ``cost_analysis`` counts while-loop bodies
    ONCE (verified in EXPERIMENTS.md §Dry-run), so scanned/chunked
    programs under-report FLOPs.  We therefore lower two *unrolled*
    variants with 1 and 2 super-block repetitions and no inner chunk
    loops; ``body = probe2 - probe1`` is the exact per-super-block cost
    and ``total = probe1 + (n_super - 1) * body`` reconstructs the full
    program (plus an analytic term for the sLSTM token scan, the one loop
    that cannot be unrolled).  All probe numbers are per-device, matching
    the roofline's per-chip terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model import decode_step, init_cache, init_params, logits_fn
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optim import adamw_init

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# op mnemonics incl. async start forms; "-done" carries no new bytes
_COLLECTIVE_RE = re.compile(
    r"=\s*(.*?)\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Result bytes of every collective op in the optimized HLO (per device)."""
    out = {k: 0 for k in _COLLECTIVE_KINDS}
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_part, kind = m.groups()
        out[kind] += _shapes_bytes(shape_part)
        counts[kind] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def input_specs(cfg, shape_spec, mesh, strategy="tp"):
    """ShapeDtypeStruct stand-ins + PartitionSpecs for every model input."""
    B, S = shape_spec.global_batch, shape_spec.seq_len
    bspec = batch_pspec(mesh, global_batch=B, strategy=strategy)
    sds = jax.ShapeDtypeStruct
    if shape_spec.kind in ("train", "prefill"):
        if cfg.frontend_dim:
            tokens = sds((B, S, cfg.frontend_dim), jnp.bfloat16)
            tspec = P(bspec[0], None, None)
        else:
            tokens = sds((B, S), jnp.int32)
            tspec = bspec
        if shape_spec.kind == "train":
            labels = sds((B, S), jnp.int32)
            return {"tokens": tokens, "labels": labels}, {
                "tokens": tspec,
                "labels": bspec,
            }
        return {"tokens": tokens}, {"tokens": tspec}
    # decode: one new token against an S-long cache
    return (
        {"tokens": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)},
        {"tokens": P(bspec[0], None), "pos": P(bspec[0])},
    )


def _build_lowerable(cfg, spec, mesh, donate=True, strategy="tp"):
    B, S = spec.global_batch, spec.seq_len
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(params_sds, mesh, strategy=strategy)
    psh = shardings(pspecs, mesh)
    inputs, ispecs = input_specs(cfg, spec, mesh, strategy=strategy)
    ish = shardings(ispecs, mesh)

    if spec.kind == "train":
        tcfg = TrainConfig(microbatches=int(dict(cfg.extra).get("microbatches", 1)))
        step_fn = make_train_step(cfg, tcfg)
        opt_sds = jax.eval_shape(lambda: adamw_init(params_sds))
        osh = {"m": shardings(pspecs, mesh), "v": shardings(pspecs, mesh)}
        rep = NamedSharding(mesh, P())
        fn = jax.jit(
            step_fn,
            in_shardings=(psh, osh, ish, rep),
            out_shardings=(psh, osh, {"loss": rep, "grad_norm": rep, "lr": rep}),
            donate_argnums=(0, 1) if donate else (),
        )
        args = (params_sds, opt_sds, inputs, jax.ShapeDtypeStruct((), jnp.int32))
    elif spec.kind == "prefill":
        fn = jax.jit(
            lambda p, t: logits_fn(p, cfg, t, last_only=True),
            in_shardings=(psh, ish["tokens"]),
        )
        args = (params_sds, inputs["tokens"])
    else:  # decode
        cache_sds = jax.eval_shape(lambda: init_cache(cfg, B, S))
        cspecs = cache_pspecs(cache_sds, mesh)
        csh = shardings(cspecs, mesh)
        fn = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos),
            in_shardings=(psh, csh, ish["tokens"], ish["pos"]),
            donate_argnums=(1,) if donate else (),
        )
        args = (params_sds, cache_sds, inputs["tokens"], inputs["pos"])
    return fn, args, params_sds


def _compile_and_analyze(fn, args, mesh):
    with mesh:
        t0 = time.perf_counter()
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # noqa: BLE001
        mem_d = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        cost_d = {
            "flops": float(cost.get("flops", -1)),
            "bytes": float(cost.get("bytes accessed", -1)),
            "transcendentals": float(cost.get("transcendentals", -1)),
        }
    except Exception as e:  # noqa: BLE001
        cost_d = {"error": str(e), "flops": 0.0, "bytes": 0.0}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_d,
        "cost": cost_d,
        "collectives": coll,
        "hlo_bytes": len(hlo),
    }


# ---------------------------------------------------------------------------
# cost probes: unrolled k-super variants -> trip-count-corrected totals
# ---------------------------------------------------------------------------


def _probe_cfg(cfg, k: int):
    p, n_super, tail = cfg.super_block()
    head = cfg.moe.first_k_dense if cfg.moe else 0
    # probes force microbatches=1: grad accumulation splits the same total
    # flops/bytes across an (uncounted) scan, so totals match production
    extra = tuple(kv for kv in cfg.extra if kv[0] != "microbatches")
    return cfg.with_(
        n_layers=head + p * k + tail,
        scan_layers=False,
        attn_chunk=0,
        loss_chunk=0,
        moe_chunk=0,
        ssm_chunk=0,
        extra=extra,
    )


def _slstm_correction(cfg, spec) -> float:
    """Analytic per-device FLOPs for the sLSTM token scan the probes can't
    unroll: recurrent einsum 2*4*H*dh^2 per token per layer."""
    n_slstm = sum(1 for k, _ in cfg.layer_kinds() if k == "slstm")
    if not n_slstm:
        return 0.0
    B, S = spec.global_batch, spec.seq_len
    if spec.kind == "decode":
        S = 1
    H = cfg.n_heads
    dh = cfg.d_model // H
    fwd = 2.0 * 4 * H * dh * dh * B * S * n_slstm
    mult = 3.0 if spec.kind == "train" else 1.0
    return fwd * mult  # global; converted to per-device by caller


def cost_probes(arch: str, shape_name: str, mesh, strategy="tp") -> dict:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    _, n_super, _ = cfg.super_block()

    res = {}
    for k in (1, 2):
        fn, args, _ = _build_lowerable(
            _probe_cfg(cfg, k), spec, mesh, donate=False, strategy=strategy
        )
        res[k] = _compile_and_analyze(fn, args, mesh)

    f1, f2 = res[1]["cost"]["flops"], res[2]["cost"]["flops"]
    b1, b2 = res[1]["cost"]["bytes"], res[2]["cost"]["bytes"]
    c1 = res[1]["collectives"]["total_bytes"]
    c2 = res[2]["collectives"]["total_bytes"]
    scale = n_super - 1
    slstm_extra = _slstm_correction(cfg, spec) / mesh.devices.size

    corrected = {
        "n_super": n_super,
        "flops": f1 + scale * (f2 - f1) + slstm_extra,
        "bytes": b1 + scale * (b2 - b1),
        "collective_bytes": c1 + scale * (c2 - c1),
        "slstm_extra_flops": slstm_extra,
        "probe1": {"flops": f1, "bytes": b1, "coll": c1,
                   "compile_s": res[1]["compile_s"]},
        "probe2": {"flops": f2, "bytes": b2, "coll": c2,
                   "compile_s": res[2]["compile_s"]},
        "collectives_by_kind": {
            kind: res[1]["collectives"]["bytes"][kind]
            + scale
            * (res[2]["collectives"]["bytes"][kind] - res[1]["collectives"]["bytes"][kind])
            for kind in _COLLECTIVE_KINDS
        },
    }
    return corrected


def lower_cell(arch: str, shape_name: str, mesh, verbose=True, probes=True,
               strategy="tp"):
    cfg = get_config(arch)
    spec = SHAPES[shape_name]

    fn, args, params_sds = _build_lowerable(cfg, spec, mesh, strategy=strategy)
    prod = _compile_and_analyze(fn, args, mesh)

    n_params = sum(
        int(jnp.prod(jnp.array(x.shape))) for x in jax.tree.leaves(params_sds)
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": spec.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "n_params": n_params,
        "batch": spec.global_batch,
        "seq": spec.seq_len,
        "strategy": strategy,
        "production": prod,
    }
    if probes:
        rec["corrected"] = cost_probes(arch, shape_name, mesh, strategy=strategy)
    if verbose:
        corr = rec.get("corrected", {})
        print(
            f"[dryrun] {arch} x {shape_name} ({spec.kind}) "
            f"{rec['mesh']}: compile {prod['compile_s']:.1f}s "
            f"flops/dev={corr.get('flops', prod['cost'].get('flops', 0)):.3e} "
            f"coll/dev={corr.get('collective_bytes', 0)/1e9:.3f} GB",
            flush=True,
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp", "dp"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCHS if args.arch == "all" else [args.arch]
    mesh_names = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    failures = []
    for mesh_name in mesh_names:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            shape_names = cells(arch) if args.shape == "all" else [args.shape]
            for shape_name in shape_names:
                if shape_name not in cells(arch):
                    print(f"[dryrun] SKIP {arch} x {shape_name} (not applicable)")
                    n_skip += 1
                    continue
                suffix = "" if args.strategy == "tp" else f"__{args.strategy}"
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
                )
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] cached {path}", flush=True)
                    n_ok += 1
                    continue
                try:
                    rec = lower_cell(
                        arch, shape_name, mesh, probes=not args.no_probes,
                        strategy=args.strategy,
                    )
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    n_ok += 1
                except Exception:  # noqa: BLE001
                    n_fail += 1
                    failures.append((arch, shape_name, mesh_name))
                    print(f"[dryrun] FAIL {arch} x {shape_name} ({mesh_name})")
                    traceback.print_exc()
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    for f in failures:
        print(f"[dryrun]   failed: {f}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
