"""Skim service driver — the paper's user-facing workflow (Fig. 3).

Accepts the JSON query format (Fig. 2c) and runs the near-data skim,
returning the filtered store plus the per-operation breakdown, exactly the
measurement the paper reports.  ``--mode`` selects the compared systems
(client_plain / client_opt / server_side / near_data) and ``--gbps`` the
client link tier.

  PYTHONPATH=src python -m repro.launch.serve --query query.json \
      --events 50000 --mode near_data --gbps 1
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core.engine import NetworkModel, SkimEngine
from repro.data.store import EventStore
from repro.data.synth import make_nanoaod_like


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", required=True, help="JSON query file or '-' for stdin")
    ap.add_argument("--store", default="", help="input .skim file (default: synthetic)")
    ap.add_argument("--events", type=int, default=50_000)
    ap.add_argument("--n-hlt", type=int, default=64)
    ap.add_argument("--n-filler", type=int, default=50)
    ap.add_argument("--codec", default="bitpack", choices=["bitpack", "zlib", "raw"])
    ap.add_argument("--mode", default="near_data",
                    choices=["client_plain", "client_opt", "server_side", "near_data"])
    ap.add_argument("--gbps", type=float, default=1.0)
    ap.add_argument("--out", default="", help="write the filtered store here")
    args = ap.parse_args()

    if args.query == "-":
        query = json.load(sys.stdin)
    else:
        with open(args.query) as f:
            query = json.load(f)

    if args.store:
        store = EventStore.load(args.store)
    else:
        store = make_nanoaod_like(
            args.events, n_hlt=args.n_hlt, n_filler=args.n_filler, codec=args.codec
        )

    engine = SkimEngine(store, input_link=NetworkModel(args.gbps, rtt_s=0.010))
    res = engine.run(query, mode=args.mode)

    print(f"[serve] mode={res.mode} passed {res.n_passed}/{res.n_input} "
          f"({100*res.selectivity:.2f}%)")
    print(f"[serve] plan: {res.plan.describe()}")
    for k, v in res.breakdown.as_dict().items():
        print(f"[serve]   {k:16s} {v:8.3f}s")
    print(f"[serve] busy fraction {res.busy_fraction:.2f}")
    if args.out:
        res.output.save(args.out)
        print(f"[serve] wrote {args.out}")


if __name__ == "__main__":
    main()
