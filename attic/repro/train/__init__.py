from repro.train.checkpoint import latest_step, restore, save
from repro.train.loop import make_train_step, train_loop
from repro.train.optim import adamw_init, adamw_update, cosine_lr

__all__ = [
    "make_train_step",
    "train_loop",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "save",
    "restore",
    "latest_step",
]
