"""Sharded checkpointing with reshard-on-load (elastic scaling).

Layout: ``<dir>/step_<N>/shard_<k>.npz`` + ``meta.json``.  Each leaf is
saved as host numpy keyed by its flattened tree path; on restore the
arrays are ``device_put`` against the *current* mesh's shardings — the
restoring job may run on a different mesh shape (512 -> 256 chips, etc.),
which is the elastic-scaling path (DESIGN.md §6).

Fault model: writes go to a temp dir and are atomically renamed, so a
job killed mid-checkpoint never corrupts the latest complete step; on
restart ``latest_step`` finds the newest complete checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield "/".join(path), tree


def _unflatten_into(template, flat: dict):
    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (str(k),)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(vals) if not isinstance(tree, tuple) else tuple(vals)
        return flat["/".join(path)]

    return walk(template, ())


def save(tree, step: int, ckpt_dir: str, shards: int = 1, extra_meta=None) -> str:
    """Write a complete checkpoint; returns its directory."""
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    leaves = list(_flatten(tree))
    buckets = [dict() for _ in range(shards)]
    meta = {"step": step, "keys": [], "shards": shards}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        safe = f"a{i:06d}"
        buckets[i % shards][safe] = arr
        meta["keys"].append({"key": key, "slot": safe, "shard": i % shards,
                             "dtype": str(arr.dtype), "shape": list(arr.shape)})
    if extra_meta:
        meta["extra"] = extra_meta
    for s, bucket in enumerate(buckets):
        np.savez(os.path.join(tmp, f"shard_{s:04d}.npz"), **bucket)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, d, "meta.json")
        )
    ]
    return max(steps) if steps else None


def restore(template, step: int, ckpt_dir: str, shardings=None):
    """Load a checkpoint into the template structure.

    ``shardings``: optional matching pytree of NamedShardings for the
    *current* mesh — arrays are placed directly with those shardings
    (reshard-on-load).  Without it, arrays land on the default device.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    shard_files = {}
    flat = {}
    for entry in meta["keys"]:
        s = entry["shard"]
        if s not in shard_files:
            shard_files[s] = np.load(os.path.join(d, f"shard_{s:04d}.npz"))
        flat[entry["key"]] = shard_files[s][entry["slot"]]
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree, meta
