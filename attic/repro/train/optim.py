"""Hand-rolled AdamW with f32 moments over (possibly bf16) params.

Shard-friendly: moment trees inherit the parameter PartitionSpecs, so the
optimizer adds no collectives of its own.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
    else:
        warm = jnp.float32(1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, opt_state, params, step):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
