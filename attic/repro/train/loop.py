"""Training step and loop: grad accumulation, optional gradient
compression over the pod axis, metrics, checkpoint/restart hooks."""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models.model import loss_fn
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1  # grad-accumulation steps per global batch
    optim: AdamWConfig = field(default_factory=AdamWConfig)
    log_every: int = 10
    ckpt_every: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    compress_grads: bool = False  # error-feedback int8 over the pod axis


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  ``batch`` = {'tokens': (B, S), 'labels': (B, S)}.

    With ``microbatches > 1`` the global batch is split on the batch dim
    and gradients accumulate in f32 through a ``lax.scan`` — activation
    memory scales with B/m while the params/grads stay resident; the
    data-axis reduce happens once, after accumulation (hierarchical-
    reduction friendly: GSPMD keeps per-microbatch partial sums local).
    """

    def grads_of(params, tokens, labels):
        return jax.value_and_grad(lambda p: loss_fn(p, cfg, tokens, labels))(params)

    def train_step(params, opt_state, batch, step):
        tokens, labels = batch["tokens"], batch["labels"]
        m = tcfg.microbatches
        if m > 1:
            B = tokens.shape[0]
            tk = tokens.reshape(m, B // m, -1)
            lb = labels.reshape(m, B // m, -1)

            def body(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs
                loss, g = grads_of(params, t, l)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), (tk, lb)
            )
            loss = loss / m
            grads = jax.tree.map(lambda g: g / m, grads)
        else:
            loss, grads = grads_of(params, tokens, labels)

        if tcfg.compress_grads:
            from repro.distributed.compression import ef_quantize_tree

            grads, qerr = ef_quantize_tree(grads)
        params, opt_state, om = adamw_update(
            tcfg.optim, grads, opt_state, params, step
        )
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def train_loop(
    cfg,
    params,
    data_iter,
    tcfg: TrainConfig,
    n_steps: int,
    start_step: int = 0,
    mesh=None,
    save_fn=None,
    log_fn=print,
):
    """Host-level loop: deterministic resume (data_iter keyed by step),
    periodic checkpointing, throughput metrics."""
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg), static_argnames=())
    history = []
    t0 = time.perf_counter()
    for step in range(start_step, n_steps):
        batch = data_iter(step)
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.int32(step)
        )
        if step % tcfg.log_every == 0 or step == n_steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            log_fn(
                f"step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)"
            )
            history.append({"step": step, "loss": loss})
        if save_fn is not None and step and step % tcfg.ckpt_every == 0:
            save_fn(params, opt_state, step)
    return params, opt_state, history
