"""Fault tolerance and straggler mitigation (host-control-plane layer).

At thousand-node scale the failure model is: nodes die mid-step, restart
with a fresh process, and must rejoin deterministically.  The pieces here
are deliberately framework-level (they do not depend on jax internals):

  * deterministic data order — every batch is a pure function of
    (seed, step), so any restart replays identically (exactly-once
    training semantics given checkpoint step),
  * checkpoint/restart — atomic checkpoints via ``train.checkpoint``;
    ``resume`` picks the newest complete step and rebuilds state on the
    *current* mesh (elastic re-meshing),
  * straggler mitigation — the skim/data pipeline is basket-granular, so
    slow shards shed baskets to fast ones (work stealing) based on
    observed per-shard service times; the model-step itself is SPMD
    (synchronous), so stragglers are attacked where slack exists: input
    pipeline and checkpoint I/O.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.train import checkpoint as ckpt


@dataclass
class ShardHealth:
    """Tracks per-data-shard service times; drives work stealing."""

    n_shards: int
    ema: np.ndarray = field(default=None)
    alpha: float = 0.3

    def __post_init__(self):
        if self.ema is None:
            self.ema = np.ones(self.n_shards, dtype=np.float64)

    def observe(self, shard: int, seconds: float) -> None:
        self.ema[shard] = (1 - self.alpha) * self.ema[shard] + self.alpha * seconds

    def is_straggler(self, shard: int, factor: float = 2.0) -> bool:
        return self.ema[shard] > factor * np.median(self.ema)


def rebalance(assignments: dict[int, list], health: ShardHealth,
              factor: float = 2.0) -> dict[int, list]:
    """Move work items (baskets) from straggler shards to the fastest ones.

    ``assignments``: shard -> list of work items.  Returns a new mapping;
    steals half of each straggler's queue, round-robin to the fastest
    non-straggler shards.
    """
    out = {k: list(v) for k, v in assignments.items()}
    order = np.argsort(health.ema)  # fastest first
    fast = [int(s) for s in order if not health.is_straggler(int(s), factor)]
    if not fast:
        return out
    fi = 0
    for s in range(health.n_shards):
        if health.is_straggler(s, factor) and len(out.get(s, [])) > 1:
            q = out[s]
            steal, keep = q[len(q) // 2 :], q[: len(q) // 2]
            out[s] = keep
            for item in steal:
                out[fast[fi % len(fast)]].append(item)
                fi += 1
    return out


def resume(template, ckpt_dir: str, shardings=None):
    """Restore the newest complete checkpoint; returns (tree, step) or
    (template, 0) when starting fresh."""
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return template, 0
    tree, meta = ckpt.restore(template, step, ckpt_dir, shardings=shardings)
    return tree, int(meta["step"]) + 1


class FailureInjector:
    """Deterministic failure schedule for integration tests: raises at
    configured steps, once each."""

    def __init__(self, fail_at: list[int]):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")
