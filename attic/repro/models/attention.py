"""Attention mixers: GQA/MQA/MHA, sliding-window, and MLA (DeepSeek-V2).

Train path supports q-chunked online-softmax (jnp flash) for long
sequences; decode path updates a preallocated KV cache at ``pos``.
MLA caches the 512-dim compressed KV + the shared rope key — the
architecture's KV-compression property survives into serving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init, apply_rope, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_attn(key, cfg, dtype):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, H * hd), dtype=dtype),
        "wk": _init(ks[1], (d, Hkv * hd), dtype=dtype),
        "wv": _init(ks[2], (d, Hkv * hd), dtype=dtype),
        "wo": _init(ks[3], (H * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(params, cfg, x, positions):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D), mask: (Sq,Sk) or (B,Sq,Sk) or None."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qh = q.reshape(B, Sq, Hkv, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qh, k).astype(jnp.float32) * scale
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(B, Sq, H, D)


def _causal_window_mask(Sq, Sk, q_off, window):
    rows = q_off + jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    m = rows >= cols
    if window:
        m = m & (cols > rows - window)
    return m


def attn_forward(params, cfg, x, positions, local: bool = False):
    """Full-sequence (train/prefill) attention. Returns (out, (k, v))."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, cfg, x, positions)
    scale = 1.0 / np.sqrt(cfg.hd)
    window = cfg.window if local else 0

    if cfg.attn_chunk and S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
        # q-chunked: peak logits tensor is (B, H, chunk, S)
        C = cfg.attn_chunk
        n = S // C

        def body(_, qc_off):
            qc, off = qc_off
            mask = _causal_window_mask(C, S, off, window) if cfg.causal else None
            return _, _sdpa(qc, k, v, mask, scale)

        qs = q.reshape(B, n, C, cfg.n_heads, cfg.hd).swapaxes(0, 1)
        offs = jnp.arange(n) * C
        _, outs = jax.lax.scan(body, None, (qs, offs))
        out = outs.swapaxes(0, 1).reshape(B, S, cfg.n_heads, cfg.hd)
    else:
        mask = _causal_window_mask(S, S, 0, window) if cfg.causal else None
        out = _sdpa(q, k, v, mask, scale)

    return out.reshape(B, S, -1) @ params["wo"], (k, v)


def attn_decode(params, cfg, x, cache, pos, local: bool = False):
    """One-token decode. cache = {'k','v'} (B, S_max, Hkv, hd); pos (B,) int32."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(params, cfg, x, pos[:, None])
    k_cache, v_cache = cache["k"], cache["v"]
    S_max = k_cache.shape[1]
    if local and cfg.window and cfg.window < S_max:
        # ring buffer over the window
        slot = pos % cfg.window
    else:
        slot = pos
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v_new[:, 0])

    S_c = k_cache.shape[1]
    if local and cfg.window and cfg.window < S_max:
        valid = jnp.arange(S_c)[None, :] <= pos[:, None]  # ring: all written slots
        valid = valid | (pos[:, None] >= cfg.window)
    else:
        valid = jnp.arange(S_c)[None, :] <= pos[:, None]
    scale = 1.0 / np.sqrt(cfg.hd)
    mask = valid[:, None, :]  # (B, 1, S_c) -> broadcast as (B, Sq=1, Sk)
    out = _sdpa(q, k_cache, v_cache, mask.astype(bool), scale)
    return out.reshape(B, 1, -1) @ params["wo"], {"k": k_cache, "v": v_cache}


def init_attn_cache(cfg, batch, s_max, dtype, local=False):
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    S = min(cfg.window, s_max) if (local and cfg.window) else s_max
    return {
        "k": jnp.zeros((batch, S, Hkv, hd), dtype),
        "v": jnp.zeros((batch, S, Hkv, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV + decoupled rope key
# ---------------------------------------------------------------------------


def init_mla(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rq, rd = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": _init(ks[0], (d, rq), dtype=dtype),
        "q_a_norm": jnp.ones((rq,), dtype),
        "wq_b": _init(ks[1], (rq, H * (hd + rd)), dtype=dtype),
        "wkv_a": _init(ks[2], (d, r + rd), dtype=dtype),
        "kv_a_norm": jnp.ones((r,), dtype),
        "wk_b": _init(ks[3], (r, H * hd), dtype=dtype),
        "wv_b": _init(ks[4], (r, H * hd), dtype=dtype),
        "wo": _init(ks[5], (H * hd, d), dtype=dtype),
    }


def _mla_qkr(params, cfg, x, positions):
    B, S, _ = x.shape
    H, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    q = rms_norm(x @ params["wq_a"], params["q_a_norm"], cfg.norm_eps) @ params["wq_b"]
    q = q.reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"]  # (B, S, r + rd)
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], params["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank :][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]  # shared across heads
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, mask):
    """Score via the compressed cache (absorbed projections)."""
    B, Sq, H, hd = q_nope.shape
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    wk_b = params["wk_b"].reshape(r, H, hd)
    wv_b = params["wv_b"].reshape(r, H, hd)
    # absorb wk_b into q: q_c (B,Sq,H,r)
    q_c = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
    scale = 1.0 / np.sqrt(hd + rd)
    logits = (
        jnp.einsum("bqhr,bkr->bhqk", q_c, c_kv)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv)  # (B,Sq,H,r)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, wv_b)
    return out.reshape(B, Sq, H * hd) @ params["wo"]


def mla_forward(params, cfg, x, positions, local: bool = False):
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, cfg, x, positions)
    mask = _causal_window_mask(S, S, 0, 0) if cfg.causal else None
    out = _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    return out, (c_kv, k_rope)


def mla_decode(params, cfg, x, cache, pos, local: bool = False):
    B = x.shape[0]
    q_nope, q_rope, c_new, kr_new = _mla_qkr(params, cfg, x, pos[:, None])
    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, pos].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, pos].set(kr_new[:, 0])
    S_c = c_kv.shape[1]
    mask = (jnp.arange(S_c)[None, :] <= pos[:, None])[:, None, :]
    out = _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def init_mla_cache(cfg, batch, s_max, dtype):
    return {
        "c_kv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, s_max, cfg.rope_head_dim), dtype),
    }
