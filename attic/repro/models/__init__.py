from repro.models.config import ModelConfig, MoESpec
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "ModelConfig",
    "MoESpec",
    "init_params",
    "init_cache",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
]
