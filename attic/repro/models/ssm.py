"""Mamba (S6) selective-state-space mixer.

Train path: chunked associative scan — outer ``lax.scan`` carries the
(B, d_inner, d_state) SSM state across sequence chunks; within a chunk the
recurrence h_t = a_t * h_{t-1} + b_t runs as a parallel associative scan.
This bounds the live (B, Lc, d_inner, d_state) tensor (DESIGN.md §6).

Decode path: single-step recurrence on (ssm state, conv ring buffer) —
O(1) per token, which is what makes ``long_500k`` run for SSM/hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init

SSM_CHUNK = 128


def init_mamba(key, cfg, dtype):
    d, di, ds, dtr = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dtr
    ks = jax.random.split(key, 7)
    return {
        "in_proj": _init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": _init(ks[1], (cfg.conv_kernel, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _init(ks[2], (di, dtr + 2 * ds), dtype=dtype),
        "dt_proj": _init(ks[3], (dtr, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[4], (di, d), dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, S, di); w: (K, di) depthwise. state: (B, K-1, di) or None."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    new_state = xp[:, -(K - 1) :, :] if K > 1 else None
    return out + b, new_state


def _ssm_inputs(params, cfg, xz):
    """xz: (B, S, di) conv'd+silu'd -> (dA (B,S,di,ds) decay, dBx, C)."""
    ds, dtr = cfg.d_state, cfg.dtr
    proj = xz @ params["x_proj"]  # (B, S, dtr + 2 ds)
    dt = jax.nn.softplus(
        proj[..., :dtr] @ params["dt_proj"] + params["dt_bias"]
    ).astype(jnp.float32)  # (B, S, di)
    B_ssm = proj[..., dtr : dtr + ds].astype(jnp.float32)
    C_ssm = proj[..., dtr + ds :].astype(jnp.float32)
    A = -jnp.exp(params["A_log"])  # (di, ds)
    dA = jnp.exp(dt[..., None] * A[None, None])  # (B, S, di, ds)
    dBx = (dt * xz.astype(jnp.float32))[..., None] * B_ssm[:, :, None, :]
    return dA, dBx, C_ssm


def mamba_forward(params, cfg, x, positions=None):
    """x: (B, S, d) -> (B, S, d). Returns (out, final_state)."""
    B, S, d = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in, _ = _causal_conv(x_in, params["conv_w"], params["conv_b"])
    x_in = jax.nn.silu(x_in)

    Lc = min(cfg.ssm_chunk or S, S)
    if S % Lc:
        Lc = S
    n = S // Lc

    dA, dBx, C_ssm = _ssm_inputs(params, cfg, x_in)

    def chunk_body(h0, xs):
        dA_c, dBx_c, C_c = xs  # (B, Lc, di, ds), ..., (B, Lc, ds)

        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        # fold carried state into the first element
        b_first = dA_c[:, 0] * h0 + dBx_c[:, 0]
        b_rest = dBx_c[:, 1:]
        a = dA_c
        bs = jnp.concatenate([b_first[:, None], b_rest], axis=1)
        _, hs = jax.lax.associative_scan(combine, (a, bs), axis=1)
        y = jnp.einsum("blds,bls->bld", hs, C_c)  # (B, Lc, di)
        return hs[:, -1], y

    def outer(h, xs):
        h, y = chunk_body(h, xs)
        return h, y

    reshape = lambda t: t.reshape((B, n, Lc) + t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_final, ys = jax.lax.scan(outer, h0, (reshape(dA), reshape(dBx), reshape(C_ssm)))
    y = ys.swapaxes(0, 1).reshape(B, S, di)

    y = y + params["D"][None, None] * x_in.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], h_final


def mamba_decode(params, cfg, x, cache, pos=None):
    """x: (B, 1, d); cache: {'ssm': (B, di, ds) f32, 'conv': (B, K-1, di)}."""
    B = x.shape[0]
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in, conv_state = _causal_conv(
        x_in, params["conv_w"], params["conv_b"], state=cache["conv"]
    )
    x_in = jax.nn.silu(x_in)

    dA, dBx, C_ssm = _ssm_inputs(params, cfg, x_in)  # S=1
    h = dA[:, 0] * cache["ssm"] + dBx[:, 0]  # (B, di, ds)
    y = jnp.einsum("bds,bs->bd", h, C_ssm[:, 0])[:, None]  # (B, 1, di)
    y = y + params["D"][None, None] * x_in.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], {"ssm": h, "conv": conv_state}


def init_mamba_cache(cfg, batch, dtype):
    return {
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    }
