"""Model assembler: block patterns, scan-over-layers, train/serve entry points.

The layer stack is grouped into repeating "super-blocks" (the LCM of the
mixer pattern and the MoE period); parameters for the repeated part are
stacked with a leading ``n_super`` dim and the stack runs under
``jax.lax.scan`` — keeping the HLO (and 512-device dry-run compile time)
independent of depth.  ``first_k_dense`` exception layers and the
non-dividing remainder are unrolled.

Entry points:
  * ``forward``     — (B, S) tokens (or frontend embeds) -> final hidden
  * ``loss_fn``     — forward + chunked CE (never materializes full logits)
  * ``prefill``     — forward + cache construction (padded to ``s_max``)
  * ``decode_step`` — one-token serve step against a preallocated cache
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import _init, chunked_ce_loss, init_mlp, mlp, rms_norm


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------


def _layer_plan(cfg: ModelConfig):
    """-> (head_kinds, super_kinds, tail_kinds); each a list of
    (mixer_kind, ffn_kind) tuples; scanned part repeats super_kinds."""
    p, n_super, tail = cfg.super_block()
    head = cfg.moe.first_k_dense if cfg.moe else 0
    kinds = cfg.layer_kinds()
    head_kinds = kinds[:head]
    super_kinds = kinds[head : head + p]
    tail_kinds = kinds[head + n_super * p :]
    return head_kinds, super_kinds, tail_kinds, n_super


def _init_mixer(key, kind: str, cfg, dtype):
    if kind in ("attn", "attn_local"):
        if cfg.kv_lora_rank:
            return attn.init_mla(key, cfg, dtype)
        return attn.init_attn(key, cfg, dtype)
    if kind == "mamba":
        return ssm.init_mamba(key, cfg, dtype)
    if kind == "mlstm":
        return xlstm.init_mlstm(key, cfg, dtype)
    if kind == "slstm":
        return xlstm.init_slstm(key, cfg, dtype)
    raise ValueError(kind)


def _init_block(key, kinds: tuple[str, str], cfg, dtype):
    mixer_kind, ffn_kind = kinds
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "mixer": _init_mixer(k1, mixer_kind, cfg, dtype),
    }
    if ffn_kind == "dense":
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, gated=cfg.mlp_gated)
    elif ffn_kind == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = moe_mod.init_moe(k2, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    head_kinds, super_kinds, tail_kinds, n_super = _layer_plan(cfg)
    keys = jax.random.split(key, 8)

    if cfg.frontend_dim:
        embed = _init(keys[0], (cfg.frontend_dim, cfg.d_model), dtype=dtype)
    else:
        embed = _init(keys[0], (cfg.vocab, cfg.d_model), scale=0.02, dtype=dtype)
    params = {"embed": embed, "final_norm": jnp.ones((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["head"] = _init(keys[1], (cfg.d_model, cfg.vocab), dtype=dtype)

    kh = jax.random.split(keys[2], max(len(head_kinds), 1))
    params["head_layers"] = [
        _init_block(kh[i], kinds, cfg, dtype) for i, kinds in enumerate(head_kinds)
    ]

    # scanned super-blocks: list over pattern positions, each stacked n_super
    blocks = []
    for j, kinds in enumerate(super_kinds):
        kj = jax.random.split(jax.random.fold_in(keys[3], j), max(n_super, 1))
        per_rep = [_init_block(kj[r], kinds, cfg, dtype) for r in range(n_super)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    params["blocks"] = blocks

    kt = jax.random.split(keys[4], max(len(tail_kinds), 1))
    params["tail_layers"] = [
        _init_block(kt[i], kinds, cfg, dtype) for i, kinds in enumerate(tail_kinds)
    ]
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

_MIXER_FWD = {
    "attn": lambda p, c, x, pos: attn.mla_forward(p, c, x, pos)
    if c.kv_lora_rank
    else attn.attn_forward(p, c, x, pos),
    "attn_local": lambda p, c, x, pos: attn.attn_forward(p, c, x, pos, local=True),
    "mamba": ssm.mamba_forward,
    "mlstm": xlstm.mlstm_forward,
    "slstm": xlstm.slstm_forward,
}


def _apply_block(bp, kinds, cfg, h, positions):
    mixer_kind, ffn_kind = kinds
    out, state = _MIXER_FWD[mixer_kind](bp["mixer"], cfg, rms_norm(h, bp["norm1"], cfg.norm_eps), positions)
    h = h + out
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "dense":
        h = h + mlp(bp["ffn"], rms_norm(h, bp["norm2"], cfg.norm_eps))
    elif ffn_kind == "moe":
        out, aux = moe_mod.moe_apply(bp["ffn"], cfg, rms_norm(h, bp["norm2"], cfg.norm_eps))
        h = h + out
    return h, aux, state


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params, cfg: ModelConfig, tokens, positions=None):
    """tokens: (B, S) int32, or (B, S, frontend_dim) float for stub frontends.
    Returns (hidden (B, S, d), aux_loss)."""
    head_kinds, super_kinds, tail_kinds, n_super = _layer_plan(cfg)
    dtype = jnp.dtype(cfg.dtype)

    if cfg.frontend_dim:
        h = tokens.astype(dtype) @ params["embed"]
        B, S = tokens.shape[:2]
    else:
        h = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
        B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    aux = jnp.zeros((), jnp.float32)
    for bp, kinds in zip(params["head_layers"], head_kinds):
        h, a, _ = _apply_block(bp, kinds, cfg, h, positions)
        aux = aux + a

    if n_super:

        def body(carry, xs):
            h, aux = carry
            for bp, kinds in zip(xs, super_kinds):
                h, a, _ = _apply_block(bp, kinds, cfg, h, positions)
                aux = aux + a
            return (h, aux), None

        if cfg.scan_layers:
            (h, aux), _ = jax.lax.scan(
                _remat(body, cfg), (h, aux), tuple(params["blocks"])
            )
        else:  # unrolled (cost probes / small models)
            body_r = _remat(body, cfg)
            for r in range(n_super):
                xs = tuple(
                    jax.tree.map(lambda x: x[r], blk) for blk in params["blocks"]
                )
                (h, aux), _ = body_r((h, aux), xs)

    for bp, kinds in zip(params["tail_layers"], tail_kinds):
        h, a, _ = _apply_block(bp, kinds, cfg, h, positions)
        aux = aux + a

    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux


def _unembed(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def loss_fn(params, cfg: ModelConfig, tokens, labels):
    """Mean next-token CE (labels pre-shifted by the pipeline) + MoE aux."""
    h, aux = forward(params, cfg, tokens)
    ce = chunked_ce_loss(h, _unembed(params, cfg), labels, cfg.loss_chunk)
    return ce + aux


def logits_fn(params, cfg: ModelConfig, tokens, last_only: bool = True):
    h, _ = forward(params, cfg, tokens)
    if last_only:
        h = h[:, -1:]
    return (h @ _unembed(params, cfg)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving: cache init + one-token decode
# ---------------------------------------------------------------------------


def _init_layer_cache(kinds, cfg, batch, s_max, dtype):
    mixer_kind, _ = kinds
    if mixer_kind in ("attn", "attn_local"):
        if cfg.kv_lora_rank:
            return attn.init_mla_cache(cfg, batch, s_max, dtype)
        return attn.init_attn_cache(
            cfg, batch, s_max, dtype, local=(mixer_kind == "attn_local")
        )
    if mixer_kind == "mamba":
        return ssm.init_mamba_cache(cfg, batch, dtype)
    if mixer_kind == "mlstm":
        return xlstm.init_mlstm_cache(cfg, batch, dtype)
    if mixer_kind == "slstm":
        return xlstm.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(mixer_kind)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    dtype = jnp.dtype(cfg.dtype)
    head_kinds, super_kinds, tail_kinds, n_super = _layer_plan(cfg)
    mk = lambda kinds: _init_layer_cache(kinds, cfg, batch, s_max, dtype)
    stack = lambda c: jax.tree.map(
        lambda x: jnp.tile(x[None], (n_super,) + (1,) * x.ndim), c
    )
    return {
        "head_layers": [mk(k) for k in head_kinds],
        "blocks": [stack(mk(k)) for k in super_kinds],
        "tail_layers": [mk(k) for k in tail_kinds],
    }


_MIXER_DEC = {
    "attn": lambda p, c, x, cache, pos: attn.mla_decode(p, c, x, cache, pos)
    if c.kv_lora_rank
    else attn.attn_decode(p, c, x, cache, pos),
    "attn_local": lambda p, c, x, cache, pos: attn.attn_decode(
        p, c, x, cache, pos, local=True
    ),
    "mamba": ssm.mamba_decode,
    "mlstm": xlstm.mlstm_decode,
    "slstm": xlstm.slstm_decode,
}


def _decode_block(bp, cache, kinds, cfg, h, pos):
    mixer_kind, ffn_kind = kinds
    out, new_cache = _MIXER_DEC[mixer_kind](
        bp["mixer"], cfg, rms_norm(h, bp["norm1"], cfg.norm_eps), cache, pos
    )
    h = h + out
    if ffn_kind == "dense":
        h = h + mlp(bp["ffn"], rms_norm(h, bp["norm2"], cfg.norm_eps))
    elif ffn_kind == "moe":
        out, _ = moe_mod.moe_apply(bp["ffn"], cfg, rms_norm(h, bp["norm2"], cfg.norm_eps))
        h = h + out
    return h, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One serve step: tokens (B, 1) int32, pos (B,) int32.
    Returns (logits (B, 1, V) f32, new cache)."""
    head_kinds, super_kinds, tail_kinds, n_super = _layer_plan(cfg)
    dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], tokens, axis=0).astype(dtype)

    new_cache = {"head_layers": [], "blocks": None, "tail_layers": []}
    for bp, c, kinds in zip(params["head_layers"], cache["head_layers"], head_kinds):
        h, nc = _decode_block(bp, c, kinds, cfg, h, pos)
        new_cache["head_layers"].append(nc)

    if n_super:

        def body(h, xs):
            bps, caches = xs
            ncs = []
            for bp, c, kinds in zip(bps, caches, super_kinds):
                h, nc = _decode_block(bp, c, kinds, cfg, h, pos)
                ncs.append(nc)
            return h, tuple(ncs)

        if cfg.scan_layers:
            h, nc_blocks = jax.lax.scan(
                body, h, (tuple(params["blocks"]), tuple(cache["blocks"]))
            )
            new_cache["blocks"] = list(nc_blocks)
        else:
            ys = []
            for r in range(n_super):
                take = lambda t: tuple(jax.tree.map(lambda x: x[r], b) for b in t)
                h, ncs = body(h, (take(params["blocks"]), take(cache["blocks"])))
                ys.append(ncs)
            # restack to match the scanned layout
            new_cache["blocks"] = [
                jax.tree.map(lambda *xs: jnp.stack(xs), *[y[j] for y in ys])
                for j in range(len(super_kinds))
            ]
    else:
        new_cache["blocks"] = []

    for bp, c, kinds in zip(params["tail_layers"], cache["tail_layers"], tail_kinds):
        h, nc = _decode_block(bp, c, kinds, cfg, h, pos)
        new_cache["tail_layers"].append(nc)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ _unembed(params, cfg)).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill (real serving path; dry-run lowers decode_step directly)
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens, s_max: int):
    """Run the prompt through the model, building a cache padded to s_max.
    Returns (last-token logits (B, V) f32, cache)."""
    B, S = tokens.shape[:2]
    cache = init_cache(cfg, B, s_max)
    h, _ = forward(params, cfg, tokens)
    logits = (h[:, -1] @ _unembed(params, cfg)).astype(jnp.float32)

    # re-run per-token decode to populate caches exactly (small-scale path;
    # shares all numerics with decode_step so serve == train semantics)
    def body(cache, t):
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        _, cache = decode_step(
            params, cfg, cache, tok, jnp.full((B,), t, jnp.int32)
        )
        return cache, None

    cache, _ = jax.lax.scan(body, cache, jnp.arange(S))
    return logits, cache


@functools.partial(jax.jit, static_argnames=("cfg", "n_tokens"))
def generate(params, cfg: ModelConfig, prompt, n_tokens: int, s_max: int = 0):
    """Greedy decode ``n_tokens`` after ``prompt`` (B, S)."""
    B, S = prompt.shape
    s_max = s_max or S + n_tokens
    logits, cache = prefill(params, cfg, prompt, s_max)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

    def body(carry, t):
        tok, cache = carry
        logits, cache = decode_step(
            params, cfg, cache, tok, jnp.full((B,), S, jnp.int32) + t
        )
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return (nxt, cache), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (tok0, cache), jnp.arange(n_tokens))
    return toks.swapaxes(0, 1)  # (B, n_tokens)
