"""Mixture-of-Experts FFN with gather-based (FLOPs-honest) dispatch.

Instead of the switch-style one-hot dispatch einsum — whose
``tokens x E x capacity x d`` contraction costs far more FLOPs than the
experts themselves at E=160 — tokens are *sorted* by expert assignment and
gathered into per-expert capacity slots with integer indexing.  The HLO
then contains only the real expert matmuls plus cheap gathers/scatters,
which keeps ``cost_analysis`` FLOPs ≈ useful FLOPs (important for the
roofline in EXPERIMENTS.md §Roofline).

Experts are sharded over the ``model`` mesh axis (expert parallelism);
the dispatch indices are computed replicated and the gather partitions on
the expert dimension.  Tokens beyond an expert's capacity are dropped
(standard capacity-factor semantics) and a load-balance auxiliary loss
keeps the router honest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init


def init_moe(key, cfg, dtype):
    sp = cfg.moe
    d, de, E = cfg.d_model, sp.d_expert, sp.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, E), dtype=jnp.float32),  # router in f32
        "w_gate": _init(ks[1], (E, d, de), dtype=dtype),
        "w_up": _init(ks[2], (E, d, de), dtype=dtype),
        "w_down": _init(ks[3], (E, de, d), dtype=dtype),
    }
    if sp.n_shared:
        sh = sp.shared_d_ff or sp.n_shared * de
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init(kk[0], (d, sh), dtype=dtype),
            "w_up": _init(kk[1], (d, sh), dtype=dtype),
            "w_down": _init(kk[2], (sh, d), dtype=dtype),
        }
    return p


def _dispatch_indices(idx, gates, E: int, cap: int):
    """idx/gates: (B, C, k) -> slot-filling index/gate tables.

    Returns (im (B, E*cap+1) int32 token index per expert slot (sentinel C
    = zero-pad token), gate_slot (B, E*cap) f32).
    """
    B, C, k = idx.shape
    Ck = C * k
    e_flat = idx.reshape(B, Ck)
    t_flat = jnp.broadcast_to(jnp.arange(C)[:, None], (C, k)).reshape(Ck)
    t_flat = jnp.broadcast_to(t_flat, (B, Ck))
    g_flat = gates.reshape(B, Ck)

    order = jnp.argsort(e_flat, axis=-1, stable=True)
    se = jnp.take_along_axis(e_flat, order, axis=-1)
    st = jnp.take_along_axis(t_flat, order, axis=-1)
    sg = jnp.take_along_axis(g_flat, order, axis=-1)

    iota = jnp.broadcast_to(jnp.arange(Ck), (B, Ck))
    is_new = jnp.concatenate(
        [jnp.ones((B, 1), bool), se[:, 1:] != se[:, :-1]], axis=-1
    )
    run_start = jax.lax.cummax(jnp.where(is_new, iota, 0), axis=1)
    rank = iota - run_start  # position within this expert's run
    keep = rank < cap

    slot = se * cap + rank  # (B, Ck) in [0, E*cap)
    slot = jnp.where(keep, slot, E * cap)  # overflow bucket

    bidx = jnp.arange(B)[:, None]
    im = jnp.full((B, E * cap + 1), C, jnp.int32)
    im = im.at[bidx, slot].set(jnp.where(keep, st, C).astype(jnp.int32))
    gate_slot = jnp.zeros((B, E * cap + 1), jnp.float32)
    gate_slot = gate_slot.at[bidx, slot].set(jnp.where(keep, sg, 0.0))
    return im[:, :-1], gate_slot[:, :-1]


def _moe_chunk(params, cfg, xc):
    """xc: (B, C, d) -> (B, C, d), aux-loss scalar."""
    sp = cfg.moe
    B, C, d = xc.shape
    E, k = sp.n_experts, sp.top_k
    cap = max(int(k * C * sp.capacity_factor / E) + 1, 4)

    logits = (xc.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, C, E)
    top_p, idx = jax.lax.top_k(probs, k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    pe = probs.mean(axis=(0, 1))
    fe = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (B * C * k)
    aux = E * jnp.sum(fe * pe) * sp.router_aux_coef

    im, gate_slot = _dispatch_indices(idx, gates, E, cap)

    x_pad = jnp.concatenate([xc, jnp.zeros((B, 1, d), xc.dtype)], axis=1)
    disp = jnp.take_along_axis(x_pad, im[..., None], axis=1)  # (B, E*cap, d)
    disp = disp.reshape(B, E, cap, d)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", disp, params["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", disp, params["w_up"])
    eout = jnp.einsum("becf,efd->becd", h, params["w_down"])  # (B, E, cap, d)

    eout = eout.reshape(B, E * cap, d) * gate_slot[..., None].astype(eout.dtype)
    out = jnp.zeros((B, C + 1, d), eout.dtype)
    out = out.at[jnp.arange(B)[:, None], im].add(eout)
    out = out[:, :C]

    if sp.n_shared:
        sh = params["shared"]
        g = jax.nn.silu(xc @ sh["w_gate"])
        out = out + (g * (xc @ sh["w_up"])) @ sh["w_down"]
    return out, aux


def moe_apply(params, cfg, x):
    """x: (B, S, d). Scans over sequence chunks to bound dispatch memory."""
    B, S, d = x.shape
    chunk = cfg.moe_chunk or S
    C = min(chunk, S)
    if S % C:
        C = S  # fallback: single chunk
    n = S // C
    if n == 1:
        return _moe_chunk(params, cfg, x)

    xs = x.reshape(B, n, C, d).swapaxes(0, 1)

    def body(acc, xc):
        out, aux = _moe_chunk(params, cfg, xc)
        return acc + aux, out

    aux, outs = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return outs.swapaxes(0, 1).reshape(B, S, d), aux / n
