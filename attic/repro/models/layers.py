"""Shared model building blocks: norms, MLP, RoPE, chunked CE loss."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def init_mlp(key, d_model, d_ff, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": _init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": _init(k3, (d_ff, d_model), dtype=dtype),
    }
    if gated:
        p["w_gate"] = _init(k1, (d_model, d_ff), dtype=dtype)
    return p


def mlp(params, x):
    if "w_gate" in params:  # SwiGLU
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# fused/chunked cross-entropy: never materializes (B, S, V) logits
# ---------------------------------------------------------------------------


def chunked_ce_loss(h, embed_out, labels, chunk: int, z_coef: float = 0.0):
    """h: (B, S, d); embed_out: (d, V); labels: (B, S) int32 -> scalar loss.

    Scans over sequence chunks so the live logits tensor is (B, chunk, V)
    — with V model-sharded this is what makes 262k-vocab training fit.
    """
    B, S, d = h.shape
    chunk = min(chunk or S, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    def chunk_loss(hc, lc):
        logits = (hc @ embed_out).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold).sum()
        z = (jnp.square(lse) * z_coef).sum() if z_coef else 0.0
        return nll + z

    def body(carry, xs):
        hc, lc = xs
        return carry + chunk_loss(hc, lc), None

    hs = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    ls = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    if rem:
        total = total + chunk_loss(h[:, -rem:], labels[:, -rem:])
    return total / (B * S)
