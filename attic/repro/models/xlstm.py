"""xLSTM mixers: mLSTM (matrix memory, parallel/quadratic train form,
O(1) recurrent decode) and sLSTM (scalar memory, sequential scan).

Follows the xLSTM paper's stabilized exponential gating.  mLSTM q/k/v use
block-diagonal per-head projections (that is what keeps xlstm-1.3b at
1.3B params); sLSTM uses block-diagonal recurrent matrices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype):
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = di // H
    ks = jax.random.split(key, 8)
    return {
        "in_proj": _init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": _init(ks[1], (cfg.conv_kernel, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": _init(ks[2], (H, dh, dh), dtype=dtype),
        "wk": _init(ks[3], (H, dh, dh), dtype=dtype),
        "wv": _init(ks[4], (H, dh, dh), dtype=dtype),
        "w_if": _init(ks[5], (di, 2 * H), scale=0.01, dtype=jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # forget-gate bias: remember
        "norm": jnp.ones((di,), dtype),
        "out_proj": _init(ks[6], (di, d), dtype=dtype),
    }


def _mlstm_qkv_gates(params, cfg, x, conv_state=None):
    from repro.models.ssm import _causal_conv

    B, S, _ = x.shape
    H = cfg.n_heads
    di = cfg.d_inner
    dh = di // H
    xz = x @ params["in_proj"]
    xm, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(xm, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    xh = xc.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"])
    k = jnp.einsum("bshd,hde->bshe", xh, params["wk"]) / jnp.sqrt(float(dh))
    v = jnp.einsum("bshd,hde->bshe", xh, params["wv"])

    gates = xc.astype(jnp.float32) @ params["w_if"]  # (B, S, 2H)
    i_pre = gates[..., :H] + params["b_i"]
    f_pre = gates[..., H:] + params["b_f"]
    return q, k, v, i_pre, f_pre, z, new_conv


def mlstm_forward(params, cfg, x, positions=None):
    """Parallel (quadratic) stabilized mLSTM. Returns (out, final_state)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    di = cfg.d_inner
    dh = di // H
    q, k, v, i_pre, f_pre, z, _ = _mlstm_qkv_gates(params, cfg, x)

    logf = jax.nn.log_sigmoid(f_pre)  # (B, S, H)
    F = jnp.cumsum(logf, axis=1)  # (B, S, H)
    # D[t, s] = F_t - F_s + i_s  for s <= t
    dmat = F[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]  # (B,t,s,H)
    tri = jnp.tril(jnp.ones((S, S), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, NEG_INF)
    m = dmat.max(axis=2, keepdims=True)  # (B, t, 1, H) row stabilizer
    dexp = jnp.exp(dmat - m)  # (B, t, s, H)

    logits = jnp.einsum("bthe,bshe->btsh", q.astype(jnp.float32), k.astype(jnp.float32))
    w = logits * dexp
    norm = jnp.maximum(jnp.abs(w.sum(axis=2)), jnp.exp(-m[:, :, 0, :]))  # (B,t,H)
    h = jnp.einsum("btsh,bshe->bthe", w.astype(v.dtype), v) / jnp.maximum(
        norm[..., None], 1e-6
    ).astype(v.dtype)

    h = h.reshape(B, S, di)
    h = rms_norm(h, params["norm"], cfg.norm_eps)
    h = h * jax.nn.silu(z)
    # final recurrent state (for handing train -> decode; cheap recompute)
    return h @ params["out_proj"], None


def mlstm_decode(params, cfg, x, cache, pos=None):
    """cache: {'C': (B,H,dh,dh) f32, 'n': (B,H,dh) f32, 'm': (B,H) f32}."""
    B = x.shape[0]
    H = cfg.n_heads
    di = cfg.d_inner
    dh = di // H
    q, k, v, i_pre, f_pre, z, new_conv = _mlstm_qkv_gates(
        params, cfg, x, conv_state=cache["conv"]
    )
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # (B, H, dh)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]  # (B, H)

    logf = jax.nn.log_sigmoid(f_pre)
    m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
    m_new = jnp.maximum(logf + m_prev, i_pre)
    alpha = jnp.exp(logf + m_prev - m_new)[..., None]
    beta = jnp.exp(i_pre - m_new)[..., None]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    C = alpha[..., None] * C_prev + beta[..., None] * kf[..., :, None] * vf[..., None, :]
    n = alpha * n_prev + beta * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new)
    )[..., None]
    h = (num / jnp.maximum(den, 1e-6)).astype(x.dtype).reshape(B, 1, di)
    h = rms_norm(h, params["norm"], cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return h @ params["out_proj"], {"C": C, "n": n, "m": m_new, "conv": new_conv}


def init_mlstm_cache(cfg, batch, dtype):
    H = cfg.n_heads
    dh = cfg.d_inner // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    return {
        "w_in": _init(ks[0], (d, 4 * d), dtype=dtype),  # z, i, f, o
        "r": _init(ks[1], (4, H, dh, dh), scale=0.3, dtype=jnp.float32),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(jnp.float32),
        "norm": jnp.ones((d,), dtype),
        "out_proj": _init(ks[2], (d, d), dtype=dtype),
    }


def _slstm_step(params, cfg, wx_t, state):
    """wx_t: (B, 4d) f32; state: (h, c, n, m) each (B, H, dh) f32."""
    B = wx_t.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    h, c, n, m = state
    rec = jnp.einsum("bhd,ghde->gbhe", h, params["r"])  # (4, B, H, dh)
    pre = wx_t.reshape(B, 4, H, dh).transpose(1, 0, 2, 3) + rec
    z_pre, i_pre, f_pre, o_pre = pre[0], pre[1], pre[2], pre[3]

    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_forward(params, cfg, x, positions=None):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    wx = (x.astype(jnp.float32) @ params["w_in"].astype(jnp.float32)) + params["b"]

    def body(state, wx_t):
        new = _slstm_step(params, cfg, wx_t, state)
        return new, new[0]

    zeros = jnp.zeros((B, H, dh), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((B, H, dh), -1e30, jnp.float32))
    _, hs = jax.lax.scan(body, state0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, params["norm"], cfg.norm_eps)
    return h @ params["out_proj"], None


def slstm_decode(params, cfg, x, cache, pos=None):
    B = x.shape[0]
    d = cfg.d_model
    wx = (x[:, 0].astype(jnp.float32) @ params["w_in"].astype(jnp.float32)) + params["b"]
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_step(params, cfg, wx, state)
    out = h.reshape(B, 1, d).astype(x.dtype)
    out = rms_norm(out, params["norm"], cfg.norm_eps)
    return out @ params["out_proj"], {"h": h, "c": c, "n": n, "m": m}


def init_slstm_cache(cfg, batch, dtype):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}
