"""Model configuration covering every assigned architecture family."""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    n_shared: int = 0  # shared ("always-on") experts
    shared_d_ff: int = 0  # hidden size of the fused shared-expert FFN
    every: int = 1  # layer i is MoE if (i - first_k_dense) % every == 0
    first_k_dense: int = 0  # leading dense-FFN layers (DeepSeek-V2 style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None  # defaults to d_model // n_heads
    mixer_pattern: tuple[str, ...] = ("attn",)  # cycled across layers
    window: int = 1024  # sliding window for 'attn_local'
    causal: bool = True
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0  # 0 disables MLA
    q_lora_rank: int = 0
    rope_head_dim: int = 64

    moe: MoESpec | None = None
    mlp_gated: bool = True  # SwiGLU vs plain GeLU 2-matrix MLP
    tie_embeddings: bool = False
    frontend_dim: int = 0  # >0: inputs are precomputed frame/patch embeddings
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # execution knobs
    loss_chunk: int = 512  # sequence chunk for the fused CE loss (0 = single chunk)
    attn_chunk: int = 0  # q-chunked online-softmax attention when S >= this (0=off)
    moe_chunk: int = 512  # sequence chunk for MoE dispatch (0 = single chunk)
    ssm_chunk: int = 128  # sequence chunk for the selective scan (0 = single chunk)
    remat: str = "full"  # none | full | dots
    scan_layers: bool = True  # lax.scan over super-blocks vs unrolled python loop

    # SSM / xLSTM
    d_inner_factor: int = 2
    d_state: int = 16
    conv_kernel: int = 4
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    extra: tuple = ()  # hashable key/value pairs; cfg must stay a static jit arg

    # ------------------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.d_inner_factor * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank if self.dt_rank else math.ceil(self.d_model / 16)

    def mixer_kind(self, i: int) -> str:
        return self.mixer_pattern[i % len(self.mixer_pattern)]

    def ffn_kind(self, i: int) -> str:
        if self.d_ff == 0 and self.moe is None:
            return "none"
        if self.moe is not None:
            if i < self.moe.first_k_dense:
                return "dense"
            if (i - self.moe.first_k_dense) % self.moe.every == 0:
                return "moe"
            return "dense" if self.d_ff else "none"
        return "dense"

    def super_block(self) -> tuple[int, int, int]:
        """(period, n_scanned_superblocks, n_tail_layers).

        The layer stack is scanned over repetitions of the combined
        mixer/FFN pattern; ``first_k_dense`` exception layers and the
        non-dividing remainder are unrolled.
        """
        p = len(self.mixer_pattern)
        if self.moe is not None:
            p = _lcm(p, self.moe.every)
        head = self.moe.first_k_dense if self.moe else 0
        body = self.n_layers - head
        n_super = body // p
        tail = body % p
        return p, n_super, tail

    def layer_kinds(self) -> list[tuple[str, str]]:
        return [(self.mixer_kind(i), self.ffn_kind(i)) for i in range(self.n_layers)]

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # -- parameter count (analytic; used for 6ND roofline) -----------------

    def param_counts(self) -> dict:
        d, hd = self.d_model, self.hd
        H, Hkv = self.n_heads, self.n_kv_heads
        counts = {"embed": self.vocab * d}
        if self.frontend_dim:
            counts["embed"] = self.frontend_dim * d
            counts["head"] = d * self.vocab
        elif not self.tie_embeddings:
            counts["head"] = d * self.vocab
        mixer = 0
        ffn_total = 0
        moe_active_extra = 0.0
        for i in range(self.n_layers):
            kind, fkind = self.mixer_kind(i), self.ffn_kind(i)
            if kind in ("attn", "attn_local"):
                if self.kv_lora_rank:
                    r, rq, rd = self.kv_lora_rank, self.q_lora_rank, self.rope_head_dim
                    m = d * rq + rq * H * (hd + rd)  # q down/up
                    m += d * (r + rd)  # kv down + rope k
                    m += r * H * 2 * hd  # kv up (k_nope, v)
                    m += H * hd * d  # out
                else:
                    m = d * H * hd + 2 * d * Hkv * hd + H * hd * d
            elif kind == "mamba":
                di, ds, dtr = self.d_inner, self.d_state, self.dtr
                m = d * 2 * di + di * self.conv_kernel + di * (dtr + 2 * ds)
                m += dtr * di + di + di * d + di  # dt proj, A(di,ds)~ + D + out
                m += di * ds
            elif kind == "mlstm":
                di = self.d_inner
                m = d * 2 * di + di * self.conv_kernel + 3 * di * di // 4 + 2 * di
                m += di * d
            elif kind == "slstm":
                m = 4 * d * d + 4 * d * d // max(self.n_heads, 1) + 4 * d + d * d
            else:
                m = 0
            mixer += m
            if fkind == "dense":
                ffn_total += (3 if self.mlp_gated else 2) * d * self.d_ff
            elif fkind == "moe":
                sp = self.moe
                ffn_total += sp.n_experts * 3 * d * sp.d_expert + d * sp.n_experts
                if sp.n_shared:
                    sh = sp.shared_d_ff or sp.n_shared * sp.d_expert
                    ffn_total += 3 * d * sh
                moe_active_extra += (sp.n_experts - sp.top_k) * 3 * d * sp.d_expert
        counts["mixer"] = mixer
        counts["ffn"] = ffn_total
        counts["norms"] = 2 * self.n_layers * d + d
        counts["total"] = sum(v for k, v in counts.items() if k != "total")
        counts["active"] = counts["total"] - moe_active_extra
        return counts


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
