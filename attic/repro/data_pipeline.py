"""Training input pipeline: near-data skim -> tokens -> global batches.

This is where the paper's contribution plugs into training: the pipeline
front-end is the two-phase skim (only filter branches are decoded for all
events; survivors' output branches feed the tokenizer), sharded over the
data axis.  Batches are a pure function of (seed, step) so restarts replay
exactly (fault.py's determinism contract).

The skim front-end runs the **pipelined fused executor** (DESIGN.md §4):
basket windows are fetched + decoded by the double-buffered
:class:`~repro.data.store.WindowPrefetcher` (re-exported here) while the
previous window filters through the fused predicate+compact device pass —
so tokenization is fed at ``max(fetch+decode, filter)`` rate per window
rather than their sum.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.engine import SkimEngine, PCIE_128G
from repro.core.query import parse_query
from repro.data.store import WindowPrefetcher  # noqa: F401  (public re-export)


@dataclass
class PipelineStats:
    events_seen: int = 0
    events_kept: int = 0
    bytes_scanned: int = 0
    bytes_kept: int = 0


class TokenPipeline:
    """Deterministic synthetic token stream (stand-in corpus).

    Batches derive from a counter-based RNG: batch(step) is identical
    across restarts and across hosts (each host slices its shard).
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.global_batch = vocab, seq_len, global_batch
        self.seed = seed

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(
            0, self.vocab, (self.global_batch, self.seq_len + 1), dtype=np.int64
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SkimTokenPipeline:
    """Skim-fed pipeline: filter events with a JSON query, quantize the
    survivors' kinematics into tokens (synthetic physics corpus)."""

    def __init__(
        self,
        store,
        query: dict | str,
        vocab: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
    ):
        self.store = store
        self.query = parse_query(query) if not hasattr(query, "stages") else query
        self.vocab, self.seq_len, self.global_batch = vocab, seq_len, global_batch
        self.seed = seed
        self.stats = PipelineStats()
        self._tokens = self._build_token_pool()

    def _build_token_pool(self) -> np.ndarray:
        # fused+pipelined near-data skim (the SkimEngine defaults)
        engine = SkimEngine(self.store, input_link=PCIE_128G)
        res = engine.run(self.query, mode="near_data")
        self.stats.events_seen = res.n_input
        self.stats.events_kept = res.n_passed
        self.stats.bytes_scanned = res.stats.bytes_fetched
        self.stats.bytes_kept = res.extras.get("output_bytes", 0)
        out = res.output
        cols = []
        for name in sorted(out.branch_names()):
            br = out.branches[name]
            if br.jagged:
                continue
            v = out.read_flat(name).astype(np.float64)
            cols.append(v)
        if not cols or res.n_passed == 0:
            return np.zeros(1024, np.int32)
        mat = np.stack(cols, 1)  # (n_passed, n_flat)
        # rank-quantize every column into vocab bins, interleave to a stream
        toks = np.empty(mat.size, np.int32)
        for j in range(mat.shape[1]):
            order = np.argsort(np.argsort(mat[:, j]))
            toks[j :: mat.shape[1]] = (
                order * max(self.vocab - 1, 1) // max(len(order) - 1, 1)
            )
        return toks % self.vocab

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        pool = self._tokens
        n = self.global_batch
        starts = rng.integers(0, max(len(pool) - self.seq_len - 1, 1), n)
        idx = starts[:, None] + np.arange(self.seq_len + 1)[None]
        toks = pool[idx % len(pool)].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
