"""DeepSeek-V2 (236B) [moe] — MLA with kv_lora_rank 512, 2 shared + 160
routed experts top-6 [arXiv:2405.04434; hf].

60L, d_model 5120, 128 q-heads, per-expert d_ff 1536, vocab 102400.
First layer dense (d_ff 12288); MLA caches only the 512-dim compressed KV
plus a 64-dim shared rope key.
"""

from repro.models.config import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,  # dense-FFN size for the first_k_dense layer
    vocab=102400,
    head_dim=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    moe=MoESpec(
        n_experts=160,
        top_k=6,
        d_expert=1536,
        n_shared=2,
        shared_d_ff=3072,
        every=1,
        first_k_dense=1,
    ),
    attn_chunk=2048,
    extra=(("microbatches", 16),),
)

SMOKE = CONFIG.with_(
    name="deepseek-v2-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    kv_lora_rank=32,
    q_lora_rank=48,
    rope_head_dim=16,
    moe=MoESpec(
        n_experts=8, top_k=2, d_expert=64, n_shared=1, shared_d_ff=64,
        every=1, first_k_dense=1, capacity_factor=8.0,
    ),
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
