"""Architecture registry: one module per assigned architecture.

Each module exports ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family config for CPU smoke tests).  Shapes are
the assignment's four input-shape cells; applicability skips follow
DESIGN.md §4.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

ARCHS = [
    "xlstm_1p3b",
    "chameleon_34b",
    "jamba_1p5_large",
    "hubert_xlarge",
    "deepseek_v2_236b",
    "qwen2_moe_a2p7b",
    "deepseek_67b",
    "starcoder2_7b",
    "granite_20b",
    "gemma3_1b",
]

# public ids from the assignment -> module names
ALIASES = {
    "xlstm-1.3b": "xlstm_1p3b",
    "chameleon-34b": "chameleon_34b",
    "jamba-1.5-large-398b": "jamba_1p5_large",
    "hubert-xlarge": "hubert_xlarge",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "deepseek-67b": "deepseek_67b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-20b": "granite_20b",
    "gemma3-1b": "gemma3_1b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs with a sub-quadratic (SSM/hybrid/local-attention) path run long_500k
SUBQUADRATIC = {"xlstm_1p3b", "jamba_1p5_large", "gemma3_1b"}
ENCODER_ONLY = {"hubert_xlarge"}


def canonical(arch: str) -> str:
    arch = arch.replace("-", "_").replace(".", "p")
    return ALIASES.get(arch, arch)


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def cells(arch: str) -> list[str]:
    """Applicable shape cells for an arch (skips per DESIGN.md §4)."""
    a = canonical(arch)
    out = []
    for name, spec in SHAPES.items():
        if spec.kind == "decode" and a in ENCODER_ONLY:
            continue  # encoder-only: no decode step
        if name == "long_500k" and a not in SUBQUADRATIC:
            continue  # pure full-attention archs skip 500k decode
        out.append(name)
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in cells(a)]
