"""Chameleon-34B [vlm] — early-fusion, VQ image tokens in one vocabulary
[arXiv:2405.09818; unverified].

48L, d_model 8192, 64H (GQA kv=8), d_ff 22016, vocab 65536 (text + image
codes).  Early fusion means the "frontend" is just the shared token
embedding — image tokens arrive as ordinary vocab ids (stub per the
assignment).  Chameleon uses qk-norm for stability.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    attn_chunk=2048,
    extra=(("microbatches", 8),),
)

SMOKE = CONFIG.with_(
    name="chameleon-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
