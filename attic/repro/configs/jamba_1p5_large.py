"""Jamba-1.5-Large (398B) [hybrid] — Mamba+attention 1:7 interleave, MoE
[arXiv:2403.19887; hf].

72L, d_model 8192, 64H (GQA kv=8), d_ff 24576, vocab 65536; MoE 16 experts
top-2 on every other layer.  Super-block of 8: attention at position 4
(1 attn : 7 mamba), matching Jamba's published interleave.
"""

from repro.models.config import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    mixer_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoESpec(n_experts=16, top_k=2, d_expert=24576, every=2),
    d_inner_factor=2,
    d_state=16,
    conv_kernel=4,
    attn_chunk=2048,
    extra=(("microbatches", 16),),
)

SMOKE = CONFIG.with_(
    name="jamba-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    mixer_pattern=("mamba", "attn"),
    moe=MoESpec(n_experts=4, top_k=2, d_expert=128, every=2, capacity_factor=8.0),
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
