"""HuBERT-XLarge [audio] — encoder-only, wav2vec2 architecture
[arXiv:2106.07447; unverified].

48L, d_model 1280, 16H MHA (kv=16), d_ff 5120, vocab 504 (masked-unit
targets).  The CNN waveform frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed (B, S, 512) frame embeddings.
Bidirectional (non-causal); no decode step.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend_dim=512,
    attn_chunk=2048,
)

SMOKE = CONFIG.with_(
    name="hubert-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=64,
    frontend_dim=32,
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
