"""xLSTM-1.3B [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L, d_model 2048, 4 heads, d_ff=0 (mixers carry their own up-projection),
vocab 50304.  Pattern: 7 mLSTM : 1 sLSTM per super-block (xLSTM[7:1]).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    mixer_pattern=("mlstm",) * 7 + ("slstm",),
    d_inner_factor=2,
    conv_kernel=4,
    extra=(("microbatches", 4),),
)

SMOKE = CONFIG.with_(
    name="xlstm-smoke",
    n_layers=4,
    d_model=64,
    n_heads=2,
    vocab=256,
    mixer_pattern=("mlstm", "slstm"),
    dtype="float32",
    remat="none",
    loss_chunk=64,
)
