"""DeepSeek-67B [dense] — llama architecture [arXiv:2401.02954; hf].

95L, d_model 8192, 64H (GQA kv=8), d_ff 22016, vocab 102400.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    attn_chunk=2048,
    extra=(("microbatches", 16),),
)

SMOKE = CONFIG.with_(
    name="deepseek-67b-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
