"""Gemma3-1B [dense] — 5:1 local:global attention, 128k-class context
[hf:google/gemma-3-1b-pt; unverified].

26L, d_model 1152, 4H (MQA kv=1, head_dim 256), d_ff 6912, vocab 262144,
tied embeddings.  Pattern: 5 sliding-window (512) layers then 1 global.
``long_500k`` decode runs: local layers keep a 512-slot ring KV; only the
1-in-6 global layers hold full-length KV.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    tie_embeddings=True,
    window=512,
    mixer_pattern=("attn_local",) * 5 + ("attn",),
    rope_theta=1_000_000.0,
    attn_chunk=2048,
    loss_chunk=256,  # 262k vocab: keep live logits small
)

SMOKE = CONFIG.with_(
    name="gemma3-smoke",
    n_layers=3,
    d_model=64,
    n_heads=2,
    n_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab=512,
    window=16,
    mixer_pattern=("attn_local", "attn_local", "attn"),
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
