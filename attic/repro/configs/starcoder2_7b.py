"""StarCoder2-7B [dense] — GQA + RoPE code model [arXiv:2402.19173; hf].

32L, d_model 4608, 36H (GQA kv=4), d_ff 18432, vocab 49152.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    mlp_gated=False,
    attn_chunk=2048,
    extra=(("microbatches", 4),),
)

SMOKE = CONFIG.with_(
    name="starcoder2-smoke",
    n_layers=2,
    d_model=72,
    n_heads=6,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
