"""Granite-20B [dense] — llama-arch code model with MQA
[arXiv:2405.04324; hf].

52L, d_model 6144, 48H (MQA kv=1), d_ff 24576, vocab 49152.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    mlp_gated=False,
    attn_chunk=2048,
    extra=(("microbatches", 8),),
)

SMOKE = CONFIG.with_(
    name="granite-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
