"""Qwen1.5/2-MoE-A2.7B [moe] — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L, d_model 2048, 16H MHA (kv=16), per-expert d_ff 1408, vocab 151936,
shared-expert hidden 5632 (= 4 x 1408).  Every layer MoE.
"""

from repro.models.config import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=151936,
    moe=MoESpec(
        n_experts=60, top_k=4, d_expert=1408, n_shared=4, shared_d_ff=5632,
        every=1,
    ),
    attn_chunk=2048,
    extra=(("microbatches", 2),),
)

SMOKE = CONFIG.with_(
    name="qwen2-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    vocab=512,
    moe=MoESpec(n_experts=8, top_k=2, d_expert=64, n_shared=2, shared_d_ff=128,
                capacity_factor=8.0),
    dtype="float32",
    remat="none",
    attn_chunk=0,
    loss_chunk=64,
)
