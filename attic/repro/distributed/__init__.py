from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    data_axes,
    param_pspecs,
    shardings,
)

__all__ = [
    "param_pspecs",
    "cache_pspecs",
    "batch_pspec",
    "data_axes",
    "shardings",
]
