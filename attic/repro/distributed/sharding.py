"""Named-axis sharding rules for the (pod, data, model) production mesh.

Strategy (DESIGN.md §6):
  * tensor parallelism over ``model``: column-parallel in-projections
    (attention qkv, FFN up/gate, expert dim for MoE), row-parallel
    out-projections; big embeddings sharded on the vocab dim,
  * data parallelism over ``pod`` x ``data``: batch dims of activations,
    token batches and KV caches,
  * decode KV caches additionally shard the *sequence* dim over ``model``
    (flash-decoding style): GSPMD turns single-token attention against an
    S-sharded cache into partial-softmax + cross-shard reduce, which is
    what bounds per-chip cache bytes at 32k/500k contexts.

Rules are name-based over the parameter tree this repo creates; anything
unknown falls back to a divisibility heuristic, and everything degrades to
replication when a dim does not divide.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# last path component -> role
_COL = {  # shard last dim over model
    "wq", "wk", "wv", "wq_b", "wk_b", "wv_b", "head",
    "in_proj", "dt_proj", "w_in", "conv_w",
}
_ROW = {  # shard first matrix dim over model
    "wo", "w_down", "out_proj", "x_proj", "w_if",
}
_REPLICATE = {
    "router", "q_norm", "k_norm", "q_a_norm", "kv_a_norm", "norm", "norm1",
    "norm2", "final_norm", "b", "b_i", "b_f", "conv_b", "dt_bias", "wq_a",
    "wkv_a",
}
_VEC_MODEL = {"D"}  # (di,) vectors living in the sharded inner dim


def _div(n: int, mesh, axis="model") -> bool:
    return n % mesh.shape[axis] == 0


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# params exempt from ZeRO data-axis sharding:
#  - 'r': consumed inside the sLSTM per-token scan — sharding re-gathers it
#    every token (observed 9 TB/step of all-gathers),
#  - mamba internals (x_proj/dt_proj/conv_w): their small output dims make
#    GSPMD reshard the (B,S,d_inner,d_state) scan activations instead of
#    gathering the weight ("involuntary full rematerialization" warnings);
#    measured 15% lower per-step collective bytes with them exempt, and
#    they are a negligible share of parameter memory.
_NO_DATA_SHARD = {"r", "x_proj", "dt_proj", "conv_w"}


def _with_data_axis(entries: list, dims, mesh, total: int, name: str = "") -> list:
    """ZeRO/FSDP hybrid: besides TP over 'model', shard the largest
    remaining divisible dim of big tensors over 'data' so params and
    optimizer moments fit per-device HBM (42 GB/dev -> ~3 GB/dev for a
    67B model on 16x16; without this the big archs simply don't fit)."""
    if total < (1 << 20) or "data" not in mesh.axis_names or name in _NO_DATA_SHARD:
        return entries
    dsize = mesh.shape["data"]
    best, best_i = 0, None
    for i, (d, e) in enumerate(zip(dims, entries)):
        if e is None and d % dsize == 0 and d > best:
            best, best_i = d, i
    if best_i is not None:
        entries[best_i] = "data"
    return entries


def _param_spec(path: tuple[str, ...], leaf, mesh) -> P:
    name = path[-1]
    shape = leaf.shape
    # scanned super-block stacks carry a leading n_super dim
    off = 1 if ("blocks" in path and leaf.ndim >= 1) else 0
    dims = shape[off:]
    nd = len(dims)
    total = 1
    for d in dims:
        total *= d

    def spec(*entries):
        entries = _with_data_axis(list(entries), dims, mesh, total, name=name)
        return P(*([None] * off + list(entries)))

    if name in _REPLICATE or nd == 0:
        return P()
    if name == "embed":
        if nd == 2 and _div(dims[0], mesh):
            return spec("model", None)  # vocab-sharded
        return P()
    if name in _VEC_MODEL and nd == 1:
        return spec("model") if _div(dims[0], mesh) else P()
    if "ffn" in path and name in ("w_gate", "w_up", "w_down") and nd == 3:
        # MoE experts: expert-parallel over model
        if _div(dims[0], mesh):
            return spec("model", None, None)
        return spec(None, None, "model") if _div(dims[2], mesh) else P()
    if name in ("w_gate", "w_up"):  # dense MLP column-parallel
        return spec(None, "model") if nd == 2 and _div(dims[1], mesh) else P()
    if name in _COL:
        if _div(dims[-1], mesh):
            return spec(*([None] * (nd - 1) + ["model"]))
        return spec(*([None] * nd))
    if name in _ROW:
        if _div(dims[0], mesh):
            return spec(*(["model"] + [None] * (nd - 1)))
        return spec(*([None] * nd))
    if name in ("A_log",):
        return spec("model", None) if _div(dims[0], mesh) else P()
    if name == "r":  # sLSTM recurrent (4, H, dh, dh)
        return spec(None, None, None, "model") if _div(dims[-1], mesh) else P()
    if name in ("wq", "wk", "wv") and nd == 3:  # mLSTM per-head (H, dh, dh)
        return spec(None, None, "model") if _div(dims[-1], mesh) else P()
    # fallback: shard the biggest divisible dim
    best, best_i = 0, None
    for i, d in enumerate(dims):
        if _div(d, mesh) and d > best and d >= 1024:
            best, best_i = d, i
    ent = [None] * nd
    if best_i is not None:
        ent[best_i] = "model"
    return spec(*ent)


def _fsdp_spec(path: tuple[str, ...], leaf, mesh) -> P:
    """ZeRO-3 / weight-gathered DP: every big tensor sharded over the FULL
    device set (all mesh axes) on its largest divisible dim; activations
    are batch-sharded over the full set too (see batch_pspec strategy).
    GSPMD all-gathers weights per layer — for batch-dominant workloads the
    per-layer weight gather is far cheaper than TP activation reduces."""
    all_axes = tuple(mesh.axis_names)
    n_all = 1
    for a in all_axes:
        n_all *= mesh.shape[a]
    off = 1 if "blocks" in path else 0
    dims = leaf.shape[off:]
    best, best_i = 0, None
    for i, d in enumerate(dims):
        if d % n_all == 0 and d > best:
            best, best_i = d, i
    if best_i is None or best < n_all:
        return P()
    ent = [None] * len(dims)
    ent[best_i] = all_axes
    return P(*([None] * off + ent))


def param_pspecs(params, mesh, strategy: str = "tp"):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).

    strategy: 'tp' (2D tensor parallel, default), 'fsdp' (ZeRO-3
    weight-gathered full DP), 'dp' (replicated params, pure DP).
    """

    def spec_fn(path, leaf):
        if strategy == "dp":
            return P()
        if strategy == "fsdp":
            return _fsdp_spec(path, leaf, mesh)
        return _param_spec(path, leaf, mesh)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
        return spec_fn(path, tree)

    return walk(params, ())


def _cache_spec(path: tuple[str, ...], leaf, mesh) -> P:
    name = path[-1]
    off = 1 if "blocks" in path else 0
    dims = leaf.shape[off:]
    nd = len(dims)
    # batch dim shards over (pod, data) only when divisible (long_500k has
    # global_batch=1 -> replicate)
    dp_size = 1
    for a in data_axes(mesh):
        dp_size *= mesh.shape[a]
    dp = data_axes(mesh) if (nd >= 1 and dims[0] % dp_size == 0) else None

    def spec(*entries):
        return P(*([None] * off + list(entries)))

    if name in ("k", "v"):  # (B, S, Hkv, hd) — sequence-sharded
        return spec(dp, "model" if _div(dims[1], mesh) else None, None, None)
    if name in ("c_kv", "k_rope"):  # (B, S, r)
        return spec(dp, "model" if _div(dims[1], mesh) else None, None)
    if name == "ssm":  # (B, di, ds)
        return spec(dp, "model" if _div(dims[1], mesh) else None, None)
    if name == "conv":  # (B, K-1, di)
        return spec(dp, None, "model" if _div(dims[2], mesh) else None)
    if name == "C":  # mLSTM (B, H, dh, dh)
        return spec(dp, None, "model" if _div(dims[2], mesh) else None, None)
    if name in ("n", "h", "c"):  # (B, H, dh)
        return spec(dp, None, "model" if _div(dims[2], mesh) else None)
    if name == "m":
        return spec(*([dp] + [None] * (nd - 1)))
    return spec(*([dp] + [None] * (nd - 1)))


def cache_pspecs(cache, mesh):
    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return tuple(t) if isinstance(tree, tuple) else t
        return _cache_spec(path, tree, mesh)

    return walk(cache, ())


def batch_pspec(mesh, global_batch: int | None = None, strategy: str = "tp") -> P:
    # fsdp/dp: the model axis joins data parallelism for the batch dim
    axes = (
        tuple(mesh.axis_names) if strategy in ("fsdp", "dp") else data_axes(mesh)
    )
    if global_batch is not None:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if global_batch % n:
            axes = data_axes(mesh)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if global_batch % n:
                return P(None, None)
    return P(axes, None)


def shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
