"""Gradient compression for the bandwidth-scarce pod (DCN) axis.

Two composable pieces (DESIGN.md §6):

  * **error-feedback int8 quantization** — per-tensor symmetric scale;
    the quantization residual is fed back into the next step's gradient
    (EF-SGD), which keeps convergence unbiased in expectation.
  * **compressed all-reduce** (shard_map): quantize per-shard to int8
    against a psum-shared max-scale, sum as int32 across the axis,
    dequantize — an 4x wire-byte reduction for the cross-pod gradient
    reduce while ICI reductions stay full-precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, scale=None):
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_quantize(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback int8 round trip: returns (g_hat, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    g_hat = dequantize_int8(q, scale)
    return g_hat, corrected - g_hat


def ef_quantize_tree(grads, errs=None):
    errs = errs or jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(ef_quantize, grads, errs)
    g_hat = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, new_err


def topk_sparsify(g: jnp.ndarray, frac: float = 0.01):
    """Keep the top-``frac`` magnitude entries (flat); zero the rest."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.size * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)


def compressed_psum(mesh, axis: str = "pod"):
    """Build a shard_map'd int8 all-reduce over ``axis``.

    fn(x sharded P()) -> mean over the axis, transported as int8+scale.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def _reduce(x):
        scale = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis) / 127.0
        scale = scale + 1e-12
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
        return total.astype(jnp.float32) * scale / n.astype(jnp.float32)

    return shard_map(
        _reduce, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False
    )
